"""Recursive-descent parser for the SPARQL subset scoped in DESIGN.md §7.

Supports: SELECT (DISTINCT) with projection / aggregates / expressions-as,
WHERE groups with triple patterns (',' ';' '.' shorthand), property paths
(`+` `*` `?` `^` `/` `|` with parentheses, SPARQL 1.1 §9), FILTER,
OPTIONAL, MINUS, UNION, BIND, GROUP BY, ORDER BY (ASC/DESC), LIMIT/OFFSET,
and the 'a' keyword for rdf:type. Terms: prefixed names (:p, rdf:type),
<iri>, numeric literals, "string" literals. Produces the algebra of
repro.core.algebra; non-trivial paths become A.PathPattern nodes carrying
a repro.core.paths.expr AST.
"""

from __future__ import annotations

import re
from typing import List, Optional, Tuple, Union

from repro.core import algebra as A
from repro.core.paths.expr import PAlt, PathExpr, PClosure, PInv, PLink, PSeq

_TOKEN_RE = re.compile(
    r"""
    (?P<WS>\s+|\#[^\n]*)
  | (?P<IRI><[^>]*>)
  | (?P<STRING>"(?:[^"\\]|\\.)*")
  | (?P<NUM>[+-]?\d+\.\d*(?:[eE][+-]?\d+)?|[+-]?\.?\d+(?:[eE][+-]?\d+)?)
  | (?P<VAR>[?$][A-Za-z_][A-Za-z0-9_]*)
  | (?P<PNAME>[A-Za-z_][A-Za-z0-9_\-]*)?:(?:[A-Za-z0-9_\-.]*[A-Za-z0-9_\-])?
  | (?P<KW>[A-Za-z][A-Za-z0-9_]*)
  | (?P<OP>\|\||&&|!=|<=|>=|[{}().,;*/+\-=<>!^?|])
    """,
    re.VERBOSE,
)

_KEYWORDS = {
    "select", "distinct", "where", "filter", "optional", "minus", "union",
    "bind", "as", "group", "by", "order", "asc", "desc", "limit", "offset",
    "count", "sum", "min", "max", "avg", "a", "bound", "having", "not", "exists",
    # builtin calls (algebra.Func; evaluated by the expression VM, §9)
    "if", "coalesce", "in", "sameterm", "isnumeric", "isiri", "isliteral",
    "strstarts", "strends", "contains", "regex",
}


class Token:
    def __init__(self, kind: str, value: str, pos: int):
        self.kind = kind
        self.value = value
        self.pos = pos

    def __repr__(self):
        return f"Token({self.kind},{self.value!r})"


def tokenize(text: str) -> List[Token]:
    out, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise SyntaxError(f"cannot tokenize at {text[pos:pos+20]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind == "WS":
            continue
        val = m.group()
        if kind == "KW" and val.lower() not in _KEYWORDS:
            # bare word in term position — treat as prefixed name w/o colon
            kind = "PNAME"
        out.append(Token(kind or "PNAME", val, m.start()))
    out.append(Token("EOF", "", len(text)))
    return out


class Parser:
    def __init__(self, text: str):
        self.toks = tokenize(text)
        self.i = 0
        self.vt = A.VarTable()
        # inside a HAVING constraint, aggregate calls are legal expression
        # primaries; they desugar to (possibly hidden) AggSpecs collected
        # here and referenced by their out var (DESIGN.md §10)
        self._agg_specs: Optional[List[A.AggSpec]] = None
        self._hidden_aggs: List[A.AggSpec] = []

    # -- token helpers ------------------------------------------------------------

    def peek(self, k: int = 0) -> Token:
        return self.toks[min(self.i + k, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept_kw(self, word: str) -> bool:
        t = self.peek()
        if t.kind == "KW" and t.value.lower() == word:
            self.next()
            return True
        return False

    def expect_kw(self, word: str) -> None:
        if not self.accept_kw(word):
            raise SyntaxError(f"expected {word.upper()} at {self.peek().value!r}")

    def accept_op(self, op: str) -> bool:
        t = self.peek()
        if t.kind == "OP" and t.value == op:
            self.next()
            return True
        return False

    def expect_op(self, op: str) -> None:
        if not self.accept_op(op):
            raise SyntaxError(f"expected {op!r} at {self.peek().value!r}")

    # -- entry --------------------------------------------------------------------

    def parse(self) -> Tuple[A.PlanNode, A.VarTable]:
        self.expect_kw("select")
        distinct = self.accept_kw("distinct")
        proj_vars: List[int] = []
        aggs: List[A.AggSpec] = []
        binds: List[Tuple[int, A.Expr]] = []
        select_all = False
        while True:
            t = self.peek()
            if t.kind == "VAR":
                proj_vars.append(self.vt.var(self.next().value))
            elif t.kind == "OP" and t.value == "*":
                self.next()
                select_all = True
            elif t.kind == "OP" and t.value == "(":
                self.next()
                agg = self._try_aggregate()
                if agg is not None:
                    func, var, dist = agg
                    self.expect_kw("as")
                    out = self.vt.var(self.next().value)
                    aggs.append(A.AggSpec(func, var, dist, out))
                    proj_vars.append(out)
                else:
                    e = self._expr()
                    self.expect_kw("as")
                    out = self.vt.var(self.next().value)
                    binds.append((out, e))
                    proj_vars.append(out)
                self.expect_op(")")
            else:
                break
        self.accept_kw("where")
        body = self._group_graph_pattern()

        group_vars: List[int] = []
        group_binds: List[Tuple[int, A.Expr]] = []
        if self.accept_kw("group"):
            self.expect_kw("by")
            while True:
                if self.peek().kind == "VAR":
                    group_vars.append(self.vt.var(self.next().value))
                elif self.peek().kind == "OP" and self.peek().value == "(":
                    # GROUP BY (expr AS ?v): desugars to BIND + var key, so
                    # the grouping key runs through the expression VM
                    self.next()
                    e = self._expr()
                    self.expect_kw("as")
                    v = self.vt.var(self.next().value)
                    self.expect_op(")")
                    group_binds.append((v, e))
                    group_vars.append(v)
                else:
                    break

        # HAVING (SPARQL 1.1 §11): one or more parenthesized constraints
        # over the aggregate output, implicitly AND-ed. Aggregate calls in
        # the constraints desugar to hidden AggSpecs (see _primary).
        having: Optional[A.Expr] = None
        if self.accept_kw("having"):
            self._agg_specs = aggs
            constraints: List[A.Expr] = []
            while self.peek().kind == "OP" and self.peek().value == "(":
                self.expect_op("(")
                constraints.append(self._expr())
                self.expect_op(")")
            self._agg_specs = None
            if not constraints:
                raise SyntaxError(
                    f"HAVING requires a parenthesized constraint at "
                    f"{self.peek().value!r}"
                )
            having = (
                constraints[0] if len(constraints) == 1
                else A.And(tuple(constraints))
            )

        # ORDER BY keys are full expressions (ASC/DESC(expr) or a bare
        # var); expression keys desugar to a BIND below
        order_specs: List[Tuple[A.Expr, bool]] = []
        if self.accept_kw("order"):
            self.expect_kw("by")
            while True:
                if self.accept_kw("asc") or self.accept_kw("desc"):
                    asc = self.toks[self.i - 1].value.lower() == "asc"
                    self.expect_op("(")
                    order_specs.append((self._expr(), asc))
                    self.expect_op(")")
                elif self.peek().kind == "VAR":
                    order_specs.append(
                        (A.VarRef(self.vt.var(self.next().value)), True)
                    )
                else:
                    break

        limit = offset = None
        # LIMIT/OFFSET in any order
        for _ in range(2):
            if self.accept_kw("limit"):
                limit = int(self.next().value)
            elif self.accept_kw("offset"):
                offset = int(self.next().value)

        node: A.PlanNode = body
        for out, e in binds:
            node = A.Extend(out, e, node)
        for v, e in group_binds:
            node = A.Extend(v, e, node)
        if having is not None:
            # SPARQL §18.2.4.4: HAVING sees only the group keys and
            # aggregate results — anything else must fail at parse time,
            # not as an internal error downstream
            allowed = (
                set(group_vars)
                | {a.out for a in aggs}
                | {a.out for a in self._hidden_aggs}
            )
            for v in A.expr_vars(having):
                if v not in allowed:
                    raise SyntaxError(
                        "HAVING may only reference group variables or "
                        f"aggregates; ?{self.vt.name(v)} is neither"
                    )
        if aggs or group_vars or having is not None:
            # grouping projects only its keys and aggregate results —
            # anything else fails here, not as an internal error downstream
            visible = set(group_vars) | {a.out for a in aggs}
            for v in proj_vars:
                if v not in visible:
                    raise SyntaxError(
                        f"SELECT variable ?{self.vt.name(v)} must be a "
                        "GROUP BY key or an aggregate result when "
                        "grouping is used"
                    )
            # hidden HAVING aggregates ride along in the spec list; the
            # final projection below strips their out columns
            node = A.GroupAgg(group_vars, aggs + self._hidden_aggs, node, having)
            if not proj_vars:
                proj_vars = group_vars + [a.out for a in aggs]
        if select_all or not proj_vars:
            hidden = {a.out for a in self._hidden_aggs}
            proj_vars = [v for v in A.plan_vars(node) if v not in hidden]
        order_keys: List[A.SortKey] = []
        order_binds: List[Tuple[int, A.Expr]] = []
        for e, asc in order_specs:
            if isinstance(e, A.VarRef):
                order_keys.append(A.SortKey(e.var, asc))
            else:
                v = self.vt.fresh("_ord")
                order_binds.append((v, e))
                order_keys.append(A.SortKey(v, asc))
        if order_binds and not distinct:
            # expression keys may reference non-projected vars: BIND the
            # key below the projection, carry it (and any non-projected
            # bare key vars) through, strip with a final re-projection
            for v, e in order_binds:
                node = A.Extend(v, e, node)
            carry = list(proj_vars)
            for k in order_keys:
                if k.var not in carry:
                    carry.append(k.var)
            node = A.Project(carry, node)
            node = A.OrderBy(order_keys, node)
            node = A.Project(proj_vars, node)
        else:
            if order_binds:
                # SPARQL: with DISTINCT, ORDER BY may only use projected
                # expressions — the keys are computed after dedup
                avail = set(proj_vars)
                for _, e in order_binds:
                    missing = [x for x in A.expr_vars(e) if x not in avail]
                    if missing:
                        raise SyntaxError(
                            "ORDER BY expressions under DISTINCT may only "
                            "use projected variables; "
                            f"?{self.vt.name(missing[0])} is not projected"
                        )
            node = A.Project(proj_vars, node)
            if distinct:
                node = A.Distinct(node)
            if order_binds:
                for v, e in order_binds:
                    node = A.Extend(v, e, node)
                node = A.OrderBy(order_keys, node)
                node = A.Project(proj_vars, node)
            elif order_keys:
                node = A.OrderBy(order_keys, node)
        if limit is not None or offset is not None:
            node = A.Slice(node, limit, offset or 0)
        if self.peek().kind != "EOF":
            raise SyntaxError(f"trailing input at {self.peek().value!r}")
        return node, self.vt

    def _try_aggregate(self) -> Optional[Tuple[str, Optional[int], bool]]:
        t = self.peek()
        if t.kind == "KW" and t.value.lower() in ("count", "sum", "min", "max", "avg"):
            func = self.next().value.lower()
            self.expect_op("(")
            dist = self.accept_kw("distinct")
            if self.accept_op("*"):
                if dist:
                    # would require whole-solution dedup, which no engine
                    # implements — reject instead of silently answering
                    # with the plain row count
                    raise SyntaxError(
                        "COUNT(DISTINCT *) is not supported; count a "
                        "specific variable instead"
                    )
                var = None
            else:
                var = self.vt.var(self.next().value)
            self.expect_op(")")
            return func, var, dist
        return None

    # -- graph patterns ----------------------------------------------------------------

    def _group_graph_pattern(self) -> A.PlanNode:
        self.expect_op("{")
        node: Optional[A.PlanNode] = None
        triples: List[A.TriplePattern] = []
        filters: List[A.Expr] = []

        def flush() -> None:
            nonlocal node, triples
            if triples:
                bgp = A.BGP(triples)
                node = bgp if node is None else A.Join(node, bgp)
                triples = []

        while not self.accept_op("}"):
            t = self.peek()
            if t.kind == "KW" and t.value.lower() == "filter":
                self.next()
                if self.accept_kw("not"):
                    self.expect_kw("exists")
                    flush()
                    sub = self._group_graph_pattern()
                    # NOT EXISTS is an anti-semi-join, NOT a MINUS: the two
                    # diverge when the inner pattern shares no variables
                    # with the outer group (SPARQL §8.3.3)
                    node = A.NotExists(node, sub) if node is not None else sub
                else:
                    self.expect_op("(")
                    filters.append(self._expr())
                    self.expect_op(")")
            elif t.kind == "KW" and t.value.lower() == "optional":
                self.next()
                flush()
                sub = self._group_graph_pattern()
                # SPARQL: a FILTER inside OPTIONAL is the left-join
                # *condition* (it may reference left-side vars), not a
                # filter on the optional pattern alone
                expr = None
                if isinstance(sub, A.Filter):
                    expr, sub = sub.expr, sub.child
                node = (
                    A.LeftJoin(node, sub, expr) if node is not None else sub
                )
            elif t.kind == "KW" and t.value.lower() == "minus":
                self.next()
                flush()
                sub = self._group_graph_pattern()
                node = A.Minus(node, sub) if node is not None else sub
            elif t.kind == "KW" and t.value.lower() == "bind":
                self.next()
                self.expect_op("(")
                e = self._expr()
                self.expect_kw("as")
                v = self.vt.var(self.next().value)
                self.expect_op(")")
                flush()
                base = node if node is not None else A.BGP([])
                node = A.Extend(v, e, base)
            elif t.kind == "OP" and t.value == "{":
                flush()
                sub = self._group_graph_pattern()
                while self.accept_kw("union"):
                    sub2 = self._group_graph_pattern()
                    sub = A.Union(sub, sub2)
                node = sub if node is None else A.Join(node, sub)
            else:
                triples.extend(self._triples_same_subject())
                self.accept_op(".")
        flush()
        if node is None:
            node = A.BGP([])
        for f in filters:
            node = A.Filter(f, node)
        return node

    def _triples_same_subject(self) -> List[Union[A.TriplePattern, A.PathPattern]]:
        s = self._slot()
        out: List[Union[A.TriplePattern, A.PathPattern]] = []
        while True:
            p_slot, p_expr = self._predicate()
            while True:
                o = self._slot()
                if p_expr is not None:
                    out.append(A.PathPattern(s, p_expr, o))
                else:
                    out.append(A.TriplePattern(s, p_slot, o))
                if not self.accept_op(","):
                    break
            if not self.accept_op(";"):
                break
            if self.peek().kind == "OP" and self.peek().value in (".", "}"):
                break
        return out

    # -- property paths (SPARQL 1.1 §9) ------------------------------------------

    _PATH_OPS = ("+", "*", "?", "/", "|", "^")

    def _predicate(self) -> Tuple[Optional[A.Slot], Optional[PathExpr]]:
        """Parse the predicate position: (slot, None) for a plain predicate
        or variable, (None, expr) for a non-trivial property path."""
        t = self.peek()
        if t.kind == "VAR":
            self.next()
            nxt = self.peek()
            if nxt.kind == "OP" and nxt.value in self._PATH_OPS:
                raise SyntaxError(
                    "property paths require a constant predicate; found "
                    f"path operator {nxt.value!r} after variable {t.value!r}"
                )
            return A.V(self.vt.var(t.value)), None
        if t.kind in ("NUM", "STRING"):  # odd but previously accepted
            return self._slot(predicate=True), None
        expr = self._path_alt()
        if isinstance(expr, PLink):
            return A.K(expr.pred), None
        return None, expr

    def _path_alt(self) -> PathExpr:
        parts = [self._path_seq()]
        while self.accept_op("|"):
            parts.append(self._path_seq())
        return parts[0] if len(parts) == 1 else PAlt(tuple(parts))

    def _path_seq(self) -> PathExpr:
        parts = [self._path_step()]
        while self.accept_op("/"):
            parts.append(self._path_step())
        return parts[0] if len(parts) == 1 else PSeq(tuple(parts))

    def _path_step(self) -> PathExpr:
        if self.accept_op("^"):
            return PInv(self._path_elt())
        return self._path_elt()

    def _path_elt(self) -> PathExpr:
        prim = self._path_primary()
        if self.accept_op("+"):
            return PClosure(prim, min_hops=1)
        if self.accept_op("*"):
            return PClosure(prim, min_hops=0)
        if self.accept_op("?"):
            return PClosure(prim, min_hops=0, max_hops=1)
        return prim

    def _path_primary(self) -> PathExpr:
        t = self.peek()
        if t.kind == "OP" and t.value == "(":
            self.next()
            e = self._path_alt()
            self.expect_op(")")
            return e
        if t.kind == "KW" and t.value == "a":
            self.next()
            return PLink("rdf:type")
        if t.kind in ("PNAME", "IRI"):
            return PLink(self.next().value)
        if t.kind == "VAR":
            raise SyntaxError(
                "property paths require a constant predicate; found "
                f"variable {t.value!r} inside a path"
            )
        raise SyntaxError(f"expected a predicate or path at {t.value!r}")

    def _slot(self, predicate: bool = False) -> A.Slot:
        t = self.next()
        if t.kind == "VAR":
            return A.V(self.vt.var(t.value))
        if t.kind == "KW" and t.value == "a" and predicate:
            return A.K("rdf:type")
        if t.kind in ("PNAME", "IRI"):
            return A.K(t.value)
        if t.kind == "NUM":
            v = float(t.value)
            return A.K(int(v) if v.is_integer() else v)
        if t.kind == "STRING":
            return A.K(t.value)
        raise SyntaxError(f"unexpected term {t.value!r}")

    # -- expressions ----------------------------------------------------------------

    def _expr(self) -> A.Expr:
        return self._or()

    def _or(self) -> A.Expr:
        terms = [self._and()]
        while self.accept_op("||"):
            terms.append(self._and())
        return terms[0] if len(terms) == 1 else A.Or(tuple(terms))

    def _and(self) -> A.Expr:
        terms = [self._cmp()]
        while self.accept_op("&&"):
            terms.append(self._cmp())
        return terms[0] if len(terms) == 1 else A.And(tuple(terms))

    def _cmp(self) -> A.Expr:
        lhs = self._add()
        t = self.peek()
        if t.kind == "OP" and t.value in ("=", "!=", "<", "<=", ">", ">="):
            op = self.next().value
            rhs = self._add()
            return A.Cmp(op, lhs, rhs)
        if self.accept_kw("in"):
            return A.Func("in", (lhs,) + self._in_list())
        if (
            t.kind == "KW" and t.value.lower() == "not"
            and self.peek(1).kind == "KW" and self.peek(1).value.lower() == "in"
        ):
            self.next()
            self.next()
            return A.Not(A.Func("in", (lhs,) + self._in_list()))
        return lhs

    def _in_list(self) -> Tuple[A.Expr, ...]:
        self.expect_op("(")
        args = [self._expr()]
        while self.accept_op(","):
            args.append(self._expr())
        self.expect_op(")")
        return tuple(args)

    def _add(self) -> A.Expr:
        lhs = self._mul()
        while True:
            t = self.peek()
            if t.kind == "OP" and t.value in ("+", "-"):
                op = self.next().value
                lhs = A.Arith(op, lhs, self._mul())
            else:
                return lhs

    def _mul(self) -> A.Expr:
        lhs = self._unary()
        while True:
            t = self.peek()
            if t.kind == "OP" and t.value in ("*", "/"):
                op = self.next().value
                lhs = A.Arith(op, lhs, self._unary())
            else:
                return lhs

    def _unary(self) -> A.Expr:
        if self.accept_op("!"):
            return A.Not(self._unary())
        return self._primary()

    def _primary(self) -> A.Expr:
        t = self.peek()
        if t.kind == "OP" and t.value == "(":
            self.next()
            e = self._expr()
            self.expect_op(")")
            return e
        if self._agg_specs is not None and t.kind == "KW" and t.value.lower() in (
            "count", "sum", "min", "max", "avg"
        ):
            # aggregate call inside HAVING: reuse a matching SELECT-clause
            # spec (so `HAVING (SUM(?v) > k)` and `(SUM(?v) AS ?s)` share
            # one accumulator) or add a hidden spec with a fresh out var
            func, var, dist = self._try_aggregate()
            for a in self._agg_specs + self._hidden_aggs:
                if (a.func, a.var, a.distinct) == (func, var, dist):
                    return A.VarRef(a.out)
            out = self.vt.fresh("_agg")
            self._hidden_aggs.append(A.AggSpec(func, var, dist, out))
            return A.VarRef(out)
        if t.kind == "KW" and t.value.lower() == "bound":
            self.next()
            self.expect_op("(")
            v = self.vt.var(self.next().value)
            self.expect_op(")")
            return A.Bound(v)
        if t.kind == "KW" and t.value.lower() in A.FUNC_ARITIES and t.value.lower() != "in":
            name = self.next().value.lower()
            self.expect_op("(")
            args = [self._expr()]
            while self.accept_op(","):
                args.append(self._expr())
            self.expect_op(")")
            lo, hi = A.FUNC_ARITIES[name]
            if len(args) < lo or (hi is not None and len(args) > hi):
                raise SyntaxError(
                    f"{name.upper()} expects {lo}"
                    + ("" if hi == lo else f"..{hi or 'n'}")
                    + f" arguments, got {len(args)}"
                )
            return A.Func(name, tuple(args))
        if t.kind == "VAR":
            return A.VarRef(self.vt.var(self.next().value))
        if t.kind == "NUM":
            v = float(self.next().value)
            return A.Lit(int(v) if v.is_integer() else v)
        if t.kind in ("PNAME", "IRI", "STRING"):
            return A.Lit(self.next().value)
        raise SyntaxError(f"unexpected expression token {t.value!r}")


def parse_query(text: str) -> Tuple[A.PlanNode, A.VarTable]:
    return Parser(text).parse()
