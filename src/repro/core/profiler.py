"""Query profiler — Listing 1/3/5-style operator-tree reports.

One reason the paper picked vectorization over code generation is that the
operator tree stays observable (§3.1). Both engines' operators carry
OpStats; this walker prints results, batches, next/skip call counts, rows
scanned from storage (the overfetch metric of §3.4) and wall-time shares.
"""

from __future__ import annotations

from typing import List

from repro.core.algebra import VarTable


def _fmt_count(n: float) -> str:
    if n >= 1e9:
        return f"{n / 1e9:.1f}B"
    if n >= 1e6:
        return f"{n / 1e6:.1f}M"
    if n >= 1e3:
        return f"{n / 1e3:.1f}K"
    return str(int(n))


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB"):
        if abs(n) < 1024:
            return f"{n:.0f}{unit}"
        n /= 1024.0
    return f"{n:.1f}GB"


def profile_tree(root, var_table: VarTable = None, pool=None) -> str:
    total = max(root.stats.wall_time, 1e-12)
    lines: List[str] = []
    if pool is not None:
        # arena report (DESIGN.md §2.3): steady-state allocations should be
        # O(plan depth) — `alloc` counts fresh buffers, `reuse` recycled ones
        s = pool.stats()
        lines.append(
            "pool: alloc: {alloc}, reuse: {reuse}, release: {release}, "
            "allocated: {ab}, copied: {cb}".format(
                alloc=_fmt_count(s["allocations"]),
                reuse=_fmt_count(s["reuses"]),
                release=_fmt_count(s["releases"]),
                ab=_fmt_bytes(s["bytes_allocated"]),
                cb=_fmt_bytes(s["bytes_copied"]),
            )
        )

    def walk(op, prefix: str, is_last: bool, is_root: bool) -> None:
        s = op.stats
        head = "" if is_root else ("'- " if is_last else "+- ")
        detail = s.detail
        if var_table is not None:
            for vid, name in enumerate(var_table.id_to_name):
                detail = detail.replace(f"?v{vid}", f"?{name}")
        parts = [f"{s.name}{detail}", f"results: {_fmt_count(s.results)}"]
        if s.batches:
            parts.append(f"batches: {_fmt_count(s.batches)}")
        parts.append(f"next: {_fmt_count(s.next_calls)}")
        if s.skip_calls:
            parts.append(f"skip: {_fmt_count(s.skip_calls)}")
        if s.rows_scanned:
            parts.append(f"scanned: {_fmt_count(s.rows_scanned)}")
        for k, v in getattr(s, "extra", {}).items():
            parts.append(
                f"{k}: {v}" if isinstance(v, float) else f"{k}: {_fmt_count(v)}"
            )
        parts.append(f"wall: {100.0 * s.wall_time / total:.1f}%")
        lines.append(prefix + head + ", ".join(parts))
        kids = op.children()
        child_prefix = prefix if is_root else prefix + ("   " if is_last else "|  ")
        for i, c in enumerate(kids):
            walk(c, child_prefix, i == len(kids) - 1, False)

    walk(root, "", True, True)
    return "\n".join(lines)


def collect_stats(root, pool=None) -> dict:
    """Aggregate tree stats for benchmark reporting."""
    agg = {
        "total_results": root.stats.results,
        "rows_scanned": 0,
        "next_calls": 0,
        "skip_calls": 0,
        "operators": 0,
    }
    if pool is not None:
        for k, v in pool.stats().items():
            agg[f"pool_{k}"] = v

    def walk(op):
        agg["operators"] += 1
        agg["rows_scanned"] += op.stats.rows_scanned
        agg["next_calls"] += op.stats.next_calls
        agg["skip_calls"] += op.stats.skip_calls
        for k, v in getattr(op.stats, "extra", {}).items():
            # per-operator counters (frontier rounds, dedup ratio, ...):
            # peaks aggregate by max, ratios are recomputed below, the
            # rest are additive counts
            if k.endswith("_peak"):
                agg[k] = max(agg.get(k, 0), v)
            elif not k.endswith("_ratio"):
                agg[k] = agg.get(k, 0) + v
        for c in op.children():
            walk(c)

    walk(root)
    if agg.get("dedup_in"):
        agg["dedup_ratio"] = round(agg["dedup_out"] / agg["dedup_in"], 3)
    return agg
