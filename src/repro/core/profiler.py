"""Query profiler — Listing 1/3/5-style operator-tree reports.

One reason the paper picked vectorization over code generation is that the
operator tree stays observable (§3.1). Both engines' operators carry
OpStats; this walker prints results, batches, next/skip call counts, rows
scanned from storage (the overfetch metric of §3.4) and wall-time shares.

With ``analyze=True`` the report becomes EXPLAIN ANALYZE (DESIGN.md §13):
each operator additionally prints the planner's cardinality estimate next
to the actual row count, and flags misestimates whose q-error
``max(est/actual, actual/est)`` reaches ``QERROR_FLAG`` — the feedback
signal adaptive re-planning consumes.
"""

from __future__ import annotations

from typing import List, Optional

from repro.core.algebra import VarTable

# q-error at or above this flags the operator as misestimated (the
# conventional "order of magnitude within 4x" threshold from the
# cardinality-estimation literature)
QERROR_FLAG = 4.0


def _fmt_count(n: float) -> str:
    if n >= 1e9:
        return f"{n / 1e9:.1f}B"
    if n >= 1e6:
        return f"{n / 1e6:.1f}M"
    if n >= 1e3:
        return f"{n / 1e3:.1f}K"
    return str(int(n))


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KB", "MB"):
        if abs(n) < 1024:
            return f"{n:.0f}{unit}"
        n /= 1024.0
    return f"{n:.1f}GB"


def _fmt_extra(v) -> str:
    """Extra-counter values: large float counts go through the K/M/B
    formatter like ints; small floats (ratios, milliseconds) print at 2
    decimals instead of full repr precision."""
    if isinstance(v, float):
        return _fmt_count(v) if abs(v) >= 1e3 else f"{v:.2f}"
    return _fmt_count(v)


def q_error(est: float, actual: float) -> float:
    """Cardinality q-error: max(est/actual, actual/est), both clamped to
    >= 1 so zero-row operators don't divide by zero (q=1 is a perfect
    estimate)."""
    e = max(float(est), 1.0)
    a = max(float(actual), 1.0)
    return max(e / a, a / e)


def _pool_delta(pool, pool_base: Optional[dict]) -> dict:
    """Pool counters attributable to this query: current stats minus the
    pre-execution snapshot (a shared Engine's pool accumulates across
    queries; without the baseline the second report includes the first
    query's allocations). ``pool`` may be a live BatchPool or an
    already-frozen stats dict (QueryResult snapshots at end of query so
    later queries on the same arena can't leak into the report)."""
    s = pool.stats() if hasattr(pool, "stats") else dict(pool)
    if not pool_base:
        return s
    return {k: v - pool_base.get(k, 0) for k, v in s.items()}


def profile_tree(root, var_table: VarTable = None, pool=None,
                 pool_base: Optional[dict] = None, analyze: bool = False) -> str:
    total = max(root.stats.wall_time, 1e-12)
    lines: List[str] = []
    if pool is not None:
        # arena report (DESIGN.md §2.3): steady-state allocations should be
        # O(plan depth) — `alloc` counts fresh buffers, `reuse` recycled ones
        s = _pool_delta(pool, pool_base)
        lines.append(
            "pool: alloc: {alloc}, reuse: {reuse}, release: {release}, "
            "allocated: {ab}, copied: {cb}".format(
                alloc=_fmt_count(s["allocations"]),
                reuse=_fmt_count(s["reuses"]),
                release=_fmt_count(s["releases"]),
                ab=_fmt_bytes(s["bytes_allocated"]),
                cb=_fmt_bytes(s["bytes_copied"]),
            )
        )

    def walk(op, prefix: str, is_last: bool, is_root: bool) -> None:
        s = op.stats
        head = "" if is_root else ("'- " if is_last else "+- ")
        detail = s.detail
        if var_table is not None:
            for vid, name in enumerate(var_table.id_to_name):
                detail = detail.replace(f"?v{vid}", f"?{name}")
        parts = [f"{s.name}{detail}", f"results: {_fmt_count(s.results)}"]
        est = getattr(s, "est_rows", None)
        if analyze and est is not None:
            q = q_error(est, s.results)
            flag = f" MISEST(q={q:.1f})" if q >= QERROR_FLAG else ""
            src = (
                "(source=feedback)"
                if getattr(s, "est_source", "stats") == "feedback"
                else ""
            )
            parts.append(f"est: {_fmt_count(est)}{src}{flag}")
        if s.batches:
            parts.append(f"batches: {_fmt_count(s.batches)}")
        parts.append(f"next: {_fmt_count(s.next_calls)}")
        if s.skip_calls:
            parts.append(f"skip: {_fmt_count(s.skip_calls)}")
        if s.rows_scanned:
            parts.append(f"scanned: {_fmt_count(s.rows_scanned)}")
        for k, v in getattr(s, "extra", {}).items():
            parts.append(f"{k}: {_fmt_extra(v)}")
        parts.append(f"wall: {100.0 * s.wall_time / total:.1f}%")
        lines.append(prefix + head + ", ".join(parts))
        kids = op.children()
        child_prefix = prefix if is_root else prefix + ("   " if is_last else "|  ")
        for i, c in enumerate(kids):
            walk(c, child_prefix, i == len(kids) - 1, False)

    walk(root, "", True, True)
    return "\n".join(lines)


def collect_stats(root, pool=None, pool_base: Optional[dict] = None) -> dict:
    """Aggregate tree stats for benchmark reporting.

    Aggregation rules for per-operator ``extra`` counters: ``*_peak`` keys
    take the max across operators, ``*_ratio`` keys are recomputed from
    their aggregated numerator/denominator (never summed), everything else
    is an additive count. ``pool_base`` subtracts a pre-execution
    snapshot so shared-pool counters report this query's delta.
    """
    agg = {
        "total_results": root.stats.results,
        "rows_scanned": 0,
        "next_calls": 0,
        "skip_calls": 0,
        "operators": 0,
    }
    if pool is not None:
        for k, v in _pool_delta(pool, pool_base).items():
            agg[f"pool_{k}"] = v
    qmax = 0.0

    def walk(op):
        nonlocal qmax
        agg["operators"] += 1
        agg["rows_scanned"] += op.stats.rows_scanned
        agg["next_calls"] += op.stats.next_calls
        agg["skip_calls"] += op.stats.skip_calls
        est = getattr(op.stats, "est_rows", None)
        if est is not None:
            qmax = max(qmax, q_error(est, op.stats.results))
        for k, v in getattr(op.stats, "extra", {}).items():
            # per-operator counters (frontier rounds, dedup ratio, ...):
            # peaks aggregate by max, ratios are recomputed below, the
            # rest are additive counts
            if k.endswith("_peak"):
                agg[k] = max(agg.get(k, 0), v)
            elif not k.endswith("_ratio"):
                agg[k] = agg.get(k, 0) + v
        for c in op.children():
            walk(c)

    walk(root)
    if agg.get("dedup_in"):
        agg["dedup_ratio"] = round(agg["dedup_out"] / agg["dedup_in"], 3)
    if qmax:
        agg["max_q_error"] = round(qmax, 2)
    return agg
