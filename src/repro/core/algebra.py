"""SPARQL algebra: logical plan nodes + expression AST (paper §2.1).

The optimizer rewrites and orders these nodes; the translator
(`core/executor.py`) turns them into BARQ or legacy operator trees. The
node set covers the subset scoped in DESIGN.md §7 — BGPs, FILTER, OPTIONAL,
UNION, MINUS, DISTINCT, GROUP BY/aggregates, ORDER BY, LIMIT/OFFSET,
projection and BIND.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.dictionary import Term

# ---------------------------------------------------------------------------
# variables
# ---------------------------------------------------------------------------


class VarTable:
    """Query-scoped variable name <-> dense id interning (paper Fig. 3:
    'variables are also represented by IDs during execution')."""

    def __init__(self) -> None:
        self.name_to_id: Dict[str, int] = {}
        self.id_to_name: List[str] = []

    def var(self, name: str) -> int:
        name = name.lstrip("?")
        vid = self.name_to_id.get(name)
        if vid is None:
            vid = len(self.id_to_name)
            self.name_to_id[name] = vid
            self.id_to_name.append(name)
        return vid

    def name(self, vid: int) -> str:
        return self.id_to_name[vid]

    def fresh(self, hint: str = "_v") -> int:
        i = 0
        while f"{hint}{i}" in self.name_to_id:
            i += 1
        return self.var(f"{hint}{i}")


# ---------------------------------------------------------------------------
# expressions (FILTER / BIND / HAVING)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class VarRef:
    var: int


@dataclasses.dataclass(frozen=True)
class Lit:
    value: Term


@dataclasses.dataclass(frozen=True)
class Cmp:
    op: str  # '=', '!=', '<', '<=', '>', '>='
    lhs: "Expr"
    rhs: "Expr"


@dataclasses.dataclass(frozen=True)
class Arith:
    op: str  # '+', '-', '*', '/'
    lhs: "Expr"
    rhs: "Expr"


@dataclasses.dataclass(frozen=True)
class And:
    terms: Tuple["Expr", ...]


@dataclasses.dataclass(frozen=True)
class Or:
    terms: Tuple["Expr", ...]


@dataclasses.dataclass(frozen=True)
class Not:
    term: "Expr"


@dataclasses.dataclass(frozen=True)
class Bound:
    var: int


# SPARQL 1.1 builtin calls (FILTER/BIND function grammar). ``name`` is the
# lower-cased function name; the supported surface is FUNC_ARITIES below.
# String/term-classification predicates are evaluated in the *dictionary
# domain* by the expression VM (once per distinct term, broadcast per row;
# DESIGN.md §9) — Func keeps them first-class in the algebra so the planner
# can compile them like any other expression node.
@dataclasses.dataclass(frozen=True)
class Func:
    name: str  # 'if', 'coalesce', 'in', 'sameterm', 'isnumeric', ...
    args: Tuple["Expr", ...]


# name -> (min_args, max_args or None for variadic)
FUNC_ARITIES = {
    "if": (3, 3),
    "coalesce": (1, None),
    "in": (2, None),  # args[0] IN args[1:]
    "sameterm": (2, 2),
    "isnumeric": (1, 1),
    "isiri": (1, 1),
    "isliteral": (1, 1),
    "strstarts": (2, 2),
    "strends": (2, 2),
    "contains": (2, 2),
    "regex": (2, 3),
}


Expr = Union[VarRef, Lit, Cmp, Arith, And, Or, Not, Bound, Func]


def expr_vars(e: Expr) -> Tuple[int, ...]:
    if isinstance(e, VarRef):
        return (e.var,)
    if isinstance(e, Bound):
        return (e.var,)
    if isinstance(e, (Cmp, Arith)):
        return tuple(dict.fromkeys(expr_vars(e.lhs) + expr_vars(e.rhs)))
    if isinstance(e, (And, Or)):
        out: Tuple[int, ...] = ()
        for t in e.terms:
            out = out + expr_vars(t)
        return tuple(dict.fromkeys(out))
    if isinstance(e, Not):
        return expr_vars(e.term)
    if isinstance(e, Func):
        out = ()
        for a in e.args:
            out = out + expr_vars(a)
        return tuple(dict.fromkeys(out))
    return ()


# Func names whose evaluation never leaves the dictionary-code domain:
# term tests run over the per-term table, IN/sameTerm compare codes.
_CODE_FUNCS = frozenset(
    ("in", "sameterm", "isnumeric", "isiri", "isliteral",
     "strstarts", "strends", "contains", "regex")
)


def is_code_only(e: Expr) -> bool:
    """True if the expression can be evaluated purely over dictionary codes
    (equality/inequality between vars or var-vs-constant, term tests and
    dictionary-domain string predicates) — the fast path the paper
    highlights (§2.2.1: joins/hashing/sorting run over numbers)."""
    if isinstance(e, Cmp) and e.op in ("=", "!="):
        ok_l = isinstance(e.lhs, (VarRef, Lit))
        ok_r = isinstance(e.rhs, (VarRef, Lit))
        return ok_l and ok_r
    if isinstance(e, (And, Or)):
        return all(is_code_only(t) for t in e.terms)
    if isinstance(e, Not):
        return is_code_only(e.term)
    if isinstance(e, Bound):
        return True
    if isinstance(e, Func) and e.name in _CODE_FUNCS:
        return all(isinstance(a, (VarRef, Lit)) for a in e.args)
    return False


# ---------------------------------------------------------------------------
# triple patterns & plan nodes
# ---------------------------------------------------------------------------

# a slot is either a Var id wrapped or a constant term
@dataclasses.dataclass(frozen=True)
class V:  # variable slot
    id: int


@dataclasses.dataclass(frozen=True)
class K:  # constant slot
    term: Term


Slot = Union[V, K]


@dataclasses.dataclass(frozen=True)
class TriplePattern:
    s: Slot
    p: Slot
    o: Slot
    g: Optional[Slot] = None
    # legacy property-path modifier: "" (plain) or "+". Kept for
    # compatibility with older plans; the parser now emits PathPattern
    # nodes for every non-trivial path (DESIGN.md §8).
    path: str = ""


    def slots(self) -> Tuple[Slot, ...]:
        return (self.s, self.p, self.o) + ((self.g,) if self.g else ())

    def vars(self) -> Tuple[int, ...]:
        return tuple(
            dict.fromkeys(sl.id for sl in self.slots() if isinstance(sl, V))
        )


@dataclasses.dataclass(frozen=True)
class PathPattern:
    """A property-path pattern ``s path o`` (SPARQL 1.1 §9): endpoints are
    slots, the predicate position holds a compiled path expression
    (repro.core.paths.expr). Lives alongside TriplePattern inside BGPs so
    the planner's join ordering sees paths as ordinary joinable leaves."""

    s: Slot
    expr: object  # paths.expr.PathExpr (kept loose to avoid an import cycle)
    o: Slot

    def slots(self) -> Tuple[Slot, ...]:
        return (self.s, self.o)

    def vars(self) -> Tuple[int, ...]:
        return tuple(
            dict.fromkeys(sl.id for sl in self.slots() if isinstance(sl, V))
        )


@dataclasses.dataclass
class PlanNode:
    pass


@dataclasses.dataclass
class BGP(PlanNode):
    patterns: List[TriplePattern]


@dataclasses.dataclass
class Join(PlanNode):
    left: PlanNode
    right: PlanNode


@dataclasses.dataclass
class LeftJoin(PlanNode):  # OPTIONAL
    left: PlanNode
    right: PlanNode
    expr: Optional[Expr] = None


@dataclasses.dataclass
class Minus(PlanNode):
    left: PlanNode
    right: PlanNode


@dataclasses.dataclass
class NotExists(PlanNode):
    """FILTER NOT EXISTS { ... } — an anti-semi-join, kept distinct from
    Minus because the two diverge when ``right`` shares no variables with
    ``left`` (SPARQL §8.3.3): MINUS keeps every left row (nothing is
    compatible), NOT EXISTS removes *all* left rows as soon as the inner
    pattern has any solution. The planner lowers the disjoint case onto
    the degenerate constant-key anti hash join."""

    left: PlanNode
    right: PlanNode


@dataclasses.dataclass
class Union(PlanNode):
    left: PlanNode
    right: PlanNode


@dataclasses.dataclass
class Filter(PlanNode):
    expr: Expr
    child: PlanNode


@dataclasses.dataclass
class Extend(PlanNode):  # BIND (expr AS ?v)
    var: int
    expr: Expr
    child: PlanNode


@dataclasses.dataclass
class Project(PlanNode):
    vars: List[int]
    child: PlanNode


@dataclasses.dataclass
class Distinct(PlanNode):
    child: PlanNode


@dataclasses.dataclass(frozen=True)
class AggSpec:
    func: str  # 'count', 'sum', 'min', 'max', 'avg'
    var: Optional[int]  # None => COUNT(*)
    distinct: bool
    out: int  # output var id


@dataclasses.dataclass
class GroupAgg(PlanNode):
    """GROUP BY + aggregates. ``having`` is the (optional) HAVING
    constraint, evaluated over the aggregate output; aggregate calls inside
    it are desugared by the parser to hidden AggSpecs in ``aggs`` whose out
    vars the condition references (DESIGN.md §10)."""

    group_vars: List[int]
    aggs: List[AggSpec]
    child: PlanNode
    having: Optional[Expr] = None


@dataclasses.dataclass(frozen=True)
class SortKey:
    var: int
    ascending: bool = True


@dataclasses.dataclass
class OrderBy(PlanNode):
    keys: List[SortKey]
    child: PlanNode


@dataclasses.dataclass
class Slice(PlanNode):
    child: PlanNode
    limit: Optional[int] = None
    offset: int = 0


def plan_vars(node: PlanNode) -> Tuple[int, ...]:
    """Visible variables produced by a plan node."""
    if isinstance(node, BGP):
        out: Tuple[int, ...] = ()
        for p in node.patterns:
            out += p.vars()
        return tuple(dict.fromkeys(out))
    if isinstance(node, (Join, Union)):
        return tuple(dict.fromkeys(plan_vars(node.left) + plan_vars(node.right)))
    if isinstance(node, LeftJoin):
        return tuple(dict.fromkeys(plan_vars(node.left) + plan_vars(node.right)))
    if isinstance(node, (Minus, NotExists)):
        return plan_vars(node.left)
    if isinstance(node, (Filter, Distinct)):
        return plan_vars(node.child)
    if isinstance(node, Extend):
        return tuple(dict.fromkeys(plan_vars(node.child) + (node.var,)))
    if isinstance(node, Project):
        return tuple(node.vars)
    if isinstance(node, GroupAgg):
        return tuple(node.group_vars) + tuple(a.out for a in node.aggs)
    if isinstance(node, (OrderBy, Slice)):
        return plan_vars(node.child)
    raise TypeError(f"unknown plan node {type(node)}")
