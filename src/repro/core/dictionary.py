"""Bidirectional term dictionary (paper §2.2.1).

Maps RDF terms (IRIs, literals, numbers) to dense int32 IDs so that all
performance-critical computation — joins, grouping, sorting, filtering on
equality — runs over numbers. A float64 *numeric side-array* supports the
paper's noted exceptions (FILTER / BIND / ORDER BY evaluate expressions over
term values): numeric comparisons decode via one vectorized ``take`` instead
of per-row string parsing.

Hardware adaptation (DESIGN.md §2): IDs are int32, not the paper's int64 —
TPUs have no native 64-bit integer path.
"""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

Term = Union[str, int, float]


class Dictionary:
    """Insertion-ordered bidirectional term <-> int32 id mapping."""

    def __init__(self) -> None:
        self._term_to_id: Dict[Term, int] = {}
        self._id_to_term: List[Term] = []
        self._numeric: List[float] = []

    def __len__(self) -> int:
        return len(self._id_to_term)

    # -- encoding ----------------------------------------------------------

    def encode(self, term: Term) -> int:
        tid = self._term_to_id.get(term)
        if tid is None:
            tid = len(self._id_to_term)
            if tid >= np.iinfo(np.int32).max:
                raise OverflowError("dictionary exceeds int32 id space")
            self._term_to_id[term] = tid
            self._id_to_term.append(term)
            self._numeric.append(_numeric_value(term))
        return tid

    def encode_many(self, terms: Sequence[Term]) -> np.ndarray:
        return np.fromiter(
            (self.encode(t) for t in terms), dtype=np.int32, count=len(terms)
        )

    def lookup(self, term: Term) -> Optional[int]:
        """Encode-free lookup; None if the term is not in the store."""
        return self._term_to_id.get(term)

    # -- decoding ----------------------------------------------------------

    def decode(self, tid: int) -> Term:
        return self._id_to_term[tid]

    def decode_many(self, ids: Iterable[int]) -> List[Optional[Term]]:
        return [None if i < 0 else self._id_to_term[i] for i in ids]

    # -- vectorized value access (side-array) --------------------------------

    def numeric_array(self) -> np.ndarray:
        """float64 (n_terms,) — NaN for non-numeric terms. Rebuilt lazily."""
        return np.asarray(self._numeric, dtype=np.float64)

    def numeric_of(self, ids: np.ndarray) -> np.ndarray:
        arr = self.numeric_array()
        out = np.full(ids.shape, np.nan)
        valid = ids >= 0
        out[valid] = arr[ids[valid]]
        return out


def _numeric_value(term: Term) -> float:
    if isinstance(term, bool):
        return float(term)
    if isinstance(term, (int, float)):
        return float(term)
    if isinstance(term, str):
        # typed literal shorthand '"12.5"^^xsd:decimal' or plain numeric text
        s = term
        if s.startswith('"') and "^^" in s:
            s = s[1 : s.index('"', 1)]
        try:
            return float(s)
        except ValueError:
            return float("nan")
    return float("nan")
