"""Partitioned-relation substrate: the radix layout as a first-class object.

The radix-partitioned layout was grown three times over — inside the hash
join build (``kernels/radix_partition.py`` via ``KOPS.hash_build``), inside
the SIP exchange machinery, and implicitly in the sort-based aggregation
paths. This module promotes it to an operator substrate (DESIGN.md §15):
``PartitionedRelation`` holds rows fanned out by a partition hash, tracks a
memory budget, and spills whole partitions to ``.npy`` temp files using the
same mkstemp/np.save/unlink protocol as the merge join's ``_Window``
(operators/merge_join.py) — generalized from "one buffer past a row
threshold" to "largest partitions past a byte budget".

Grace hash join (Kitsuregawa's scheme, the ROADMAP "out-of-core + adaptive
(grace) hash joins" item) builds directly on it: both inputs are fanned out
once by ``partition_ids``, non-resident partitions spill, and partitions are
then joined one at a time — each small enough for the existing resident
radix build. Skewed buckets that still exceed the budget re-partition
recursively with a *different* hash multiplier per level, so a level-0
collision pile-up cannot survive to level 1.

Partition hashing deliberately uses multipliers disjoint from
``vecops._HASH_MULT``/``_MIX_MULT``: inside each loaded grace partition the
resident build runs ``KOPS.hash_build`` with the vecops family, and a
correlated grace hash would funnel every partition's rows into a handful of
internal buckets.
"""

from __future__ import annotations

import os
import tempfile
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import telemetry, vecops

# Per-recursion-level partition multipliers (Fibonacci-style odd constants,
# murmur/xxhash finalizer family). Level k uses _LEVEL_MULTS[k % 4]; all are
# distinct from vecops._HASH_MULT (0x9E3779B1 appears only at level 3, by
# which point two prior fan-outs have decorrelated the key stream).
_LEVEL_MULTS = (0xC2B2AE35, 0x27D4EB2F, 0x165667B1, 0x9E3779B1)

_MULTI_FOLD_MULT = np.uint32(0x01000193)  # FNV-1a prime for column folding


def partition_ids(
    key_hi: Optional[np.ndarray],
    key_lo: np.ndarray,
    n_parts: int,
    level: int = 0,
) -> np.ndarray:
    """Partition id per row from (hi, lo) packed key halves — the same
    representation the hash join carries (``pack_group_keys`` output split
    at bit 31). ``n_parts`` must be a power of two."""
    mixed = vecops.mix_pair(key_hi, key_lo)
    mult = np.uint32(_LEVEL_MULTS[level % len(_LEVEL_MULTS)])
    h = (mixed.astype(np.int64, copy=False).astype(np.uint32) * mult) >> np.uint32(16)
    return (h & np.uint32(n_parts - 1)).astype(np.int32)


def partition_ids_multi(
    cols: Sequence[np.ndarray], n_parts: int, level: int = 0
) -> np.ndarray:
    """Partition id from raw key columns (no span packing needed — equal
    tuples land in the same partition; cross-tuple collisions only cost
    balance, never correctness). Used by partitioned GROUP BY/DISTINCT
    where group keys never went through ``pack_group_keys``."""
    acc = cols[0].astype(np.uint32, copy=True)
    for c in cols[1:]:
        acc *= _MULTI_FOLD_MULT
        acc ^= c.astype(np.uint32, copy=False)
    mult = np.uint32(_LEVEL_MULTS[level % len(_LEVEL_MULTS)])
    h = (acc * mult) >> np.uint32(16)
    return (h & np.uint32(n_parts - 1)).astype(np.int32)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class PartitionedRelation:
    """Rows of an ``(n_vars, n)`` int32 relation fanned out into ``n_parts``
    buckets, with a budget-driven spill lifecycle.

    ``append`` scatters one block of rows by partition id (one stable
    argsort + bincount boundary scan — the same single-pass radix discipline
    as ``vecops.hash_build_order``). Each partition is a chunk list plus a
    list of spill files; when resident bytes exceed ``budget_bytes`` the
    largest resident partitions spill (mkstemp + np.save, mirroring
    ``_Window._spill``) until residency is back under half the budget —
    half, so steady-state appends don't thrash one spill per batch.

    ``take(p)`` loads partition ``p`` (concatenating spill files + resident
    chunks) and frees it immediately — grace consumers visit each partition
    exactly once, so early unlink keeps peak disk at O(non-visited).
    ``close()`` is idempotent and unlinks everything; operators route it
    through their ``_close`` hook so executor teardown reaches it even when
    a mid-query exception aborts the drain (the ISSUE-9 leak fix)."""

    def __init__(
        self,
        n_vars: int,
        n_parts: int,
        spill_dir: Optional[str] = None,
        budget_bytes: Optional[int] = None,
        pool=None,
    ):
        self.n_vars = n_vars
        self.n_parts = n_parts
        self.spill_dir = spill_dir
        self.budget_bytes = budget_bytes
        self.pool = pool
        self._chunks: List[List[np.ndarray]] = [[] for _ in range(n_parts)]
        self._files: List[List[str]] = [[] for _ in range(n_parts)]
        self.part_rows = np.zeros(n_parts, dtype=np.int64)
        self._resident_bytes = 0
        self._closed = False
        # observability counters (flow into OpStats.extra / OpenMetrics)
        self.spill_bytes = 0
        self.spill_files = 0

    # -- ingest ------------------------------------------------------------

    def append(self, cols: np.ndarray, pids: np.ndarray) -> None:
        """Scatter ``cols`` (n_vars, n) into partitions by ``pids``."""
        n = cols.shape[1]
        if n == 0:
            return
        order = np.argsort(pids, kind="stable")
        sorted_pids = pids[order]
        scattered = np.ascontiguousarray(cols[:, order])
        counts = np.bincount(sorted_pids, minlength=self.n_parts)
        starts = np.concatenate(([0], np.cumsum(counts)))
        for p in np.nonzero(counts)[0]:
            chunk = scattered[:, starts[p] : starts[p + 1]].copy()
            self._chunks[p].append(chunk)
            self.part_rows[p] += chunk.shape[1]
            self._resident_bytes += chunk.nbytes
        if self.pool is not None:
            self.pool.bytes_copied += scattered.nbytes
        self._maybe_spill()

    def append_block(self, cols: np.ndarray, pids: np.ndarray) -> None:
        """Alias kept for call-site readability: one-shot block fan-out."""
        self.append(cols, pids)

    # -- spill lifecycle ---------------------------------------------------

    def _maybe_spill(self) -> None:
        if (
            self.budget_bytes is None
            or self.spill_dir is None
            or self._resident_bytes <= self.budget_bytes
        ):
            return
        # spill largest-resident-first until under half the budget
        target = self.budget_bytes // 2
        sizes = [
            (sum(c.nbytes for c in self._chunks[p]), p)
            for p in range(self.n_parts)
            if self._chunks[p]
        ]
        sizes.sort(reverse=True)
        for nbytes, p in sizes:
            if self._resident_bytes <= target:
                break
            self._spill_partition(p, nbytes)

    def _spill_partition(self, p: int, nbytes: int) -> None:
        t0 = time.perf_counter()
        block = (
            self._chunks[p][0]
            if len(self._chunks[p]) == 1
            else np.concatenate(self._chunks[p], axis=1)
        )
        fd, path = tempfile.mkstemp(suffix=".npy", dir=self.spill_dir)
        os.close(fd)
        np.save(path, block)
        self._files[p].append(path)
        self._chunks[p] = []
        self._resident_bytes -= nbytes
        self.spill_bytes += block.nbytes
        self.spill_files += 1
        telemetry.record_dispatch(
            "partition_spill", "disk", t0, time.perf_counter() - t0
        )

    # -- consumption -------------------------------------------------------

    def load(self, p: int) -> np.ndarray:
        """Partition ``p`` as one (n_vars, rows) block (spilled + resident,
        in append order). Does not free anything."""
        blocks: List[np.ndarray] = []
        for path in self._files[p]:
            blocks.append(np.load(path))
        blocks.extend(self._chunks[p])
        if not blocks:
            return np.empty((self.n_vars, 0), dtype=np.int32)
        if len(blocks) == 1:
            return np.ascontiguousarray(blocks[0])
        return np.concatenate(blocks, axis=1)

    def take(self, p: int) -> np.ndarray:
        """``load(p)`` then free the partition (unlink its spill files)."""
        block = self.load(p)
        self._free_partition(p)
        return block

    def _free_partition(self, p: int) -> None:
        for path in self._files[p]:
            try:
                os.unlink(path)
            except OSError:
                pass
        self._files[p] = []
        self._resident_bytes -= sum(c.nbytes for c in self._chunks[p])
        self._chunks[p] = []

    # -- teardown ----------------------------------------------------------

    @property
    def resident_bytes(self) -> int:
        return self._resident_bytes

    @property
    def total_rows(self) -> int:
        return int(self.part_rows.sum())

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for p in range(self.n_parts):
            self._free_partition(p)

    def __del__(self):  # safety net; close() is the contract
        try:
            self.close()
        except Exception:
            pass


def split_block(
    cols: np.ndarray, pids: np.ndarray, n_parts: int
) -> List[Tuple[int, np.ndarray]]:
    """One-shot fan-out of a block into ``[(pid, sub_block), ...]`` without
    a PartitionedRelation — the recursive re-partition step of the grace
    join, where sub-blocks are consumed immediately."""
    order = np.argsort(pids, kind="stable")
    scattered = np.ascontiguousarray(cols[:, order])
    counts = np.bincount(pids[order], minlength=n_parts)
    starts = np.concatenate(([0], np.cumsum(counts)))
    return [
        (int(p), scattered[:, starts[p] : starts[p + 1]])
        for p in np.nonzero(counts)[0]
    ]
