from repro.core.operators.base import BatchOperator, OpStats  # noqa: F401
