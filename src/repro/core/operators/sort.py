"""Sort (ORDER BY / join-input re-sort) and materialized sources.

Sort is the canonical pipeline breaker: it materializes its whole input
(the adaptive batch sizer of upstream scans therefore ramps to the cap,
paper §3.4), sorts columnar, and re-emits batches. Two key orders:

  * code order  — for join inputs (dictionary codes are what merge joins
    compare; paper §2.2.1);
  * value order — for ORDER BY semantics, via the numeric side-array
    (NaN/non-numeric terms order after numerics, by code).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.algebra import SortKey
from repro.core.batch import MAX_BATCH, BatchPool, ColumnBatch
from repro.core.dictionary import Dictionary
from repro.core.operators.base import BatchOperator
from repro.core.vecops import sorted_search


class MaterializedSource(BatchOperator):
    """Emit a fully-materialized (n_vars, n) column block as batches.
    Supports skip() when sorted — this is what lets a sorted spill/sort
    result feed straight back into merge joins (paper §4.2: 'the output of
    a per-row operator, once sorted, can be read back as a stream of
    batches')."""

    def __init__(
        self,
        var_ids: Sequence[int],
        cols: np.ndarray,
        sorted_var: Optional[int] = None,
        batch_size: int = MAX_BATCH,
        name: str = "Materialized",
        pool: Optional[BatchPool] = None,
    ):
        self._vars = tuple(int(v) for v in var_ids)
        self.cols = cols
        self._sorted_var = sorted_var
        self.batch_size = batch_size
        self.pool = pool
        self.offset = 0
        super().__init__(name, f"{cols.shape[1]} rows")

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def sorted_by(self) -> Optional[int]:
        return self._sorted_var

    def _next(self) -> Optional[ColumnBatch]:
        n = self.cols.shape[1]
        if self.offset >= n:
            return None
        hi = min(self.offset + self.batch_size, n)
        block = self.cols[:, self.offset : hi]
        self.offset = hi
        return ColumnBatch.from_columns(
            self._vars,
            [block[i] for i in range(block.shape[0])],
            self._sorted_var,
            pool=self.pool,
        )

    def _skip(self, var: int, target: int) -> None:
        if var != self._sorted_var:
            raise ValueError("skip on unsorted var")
        key_col = self.cols[self._vars.index(var)]
        pos = int(sorted_search(key_col[self.offset :], np.asarray([target]))[0])
        self.offset += pos

    def _reset(self) -> None:
        self.offset = 0


def materialize(child: BatchOperator) -> Tuple[Tuple[int, ...], np.ndarray]:
    """Drain a child into one (n_vars, n) compacted block, recycling the
    consumed batches (pipeline-breaker boundary)."""
    vars_ = tuple(child.var_ids())
    blocks = []
    while True:
        b = child.next_batch()
        if b is None:
            break
        cb = b.compact()
        if cb.n_rows:
            order = [cb.col_index(v) for v in vars_]
            blocks.append(cb.columns[order, : cb.n_rows])  # fancy-index copy
        cb.release()
    if blocks:
        return vars_, np.concatenate(blocks, axis=1)
    return vars_, np.zeros((len(vars_), 0), dtype=np.int32)


class SortByVarOp(BatchOperator):
    """Re-sort by one variable's *code* so a merge join can consume the
    stream (the Sort(?person2) in the paper's Listing 1)."""

    def __init__(
        self,
        child: BatchOperator,
        var: int,
        batch_size: int = MAX_BATCH,
        pool: Optional[BatchPool] = None,
    ):
        self.child = child
        self.var = var
        self.batch_size = batch_size
        self.pool = pool
        self._src: Optional[MaterializedSource] = None
        super().__init__("Sort", f"(?v{var})")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.var

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _ensure(self) -> MaterializedSource:
        if self._src is None:
            vars_, cols = materialize(self.child)
            key = cols[vars_.index(self.var)]
            order = np.argsort(key, kind="stable")
            self._src = MaterializedSource(
                vars_, cols[:, order], self.var, self.batch_size,
                name="SortBuffer", pool=self.pool,
            )
        return self._src

    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def sip_keys(self, var: int) -> np.ndarray:
        """Key column for a SipFilter export (DESIGN.md §12): the sort is
        a pipeline breaker anyway, so forcing its materialization from a
        probe-side leaf costs nothing extra asymptotically."""
        src = self._ensure()
        return src.cols[src.var_ids().index(var)]

    def _skip(self, var: int, target: int) -> None:
        self._ensure().skip(var, target)

    def _reset(self) -> None:
        self.child.reset()
        self._src = None


class OrderByOp(BatchOperator):
    """ORDER BY over term values (numeric side-array; DESIGN.md §7)."""

    def __init__(
        self,
        child: BatchOperator,
        keys: Sequence[SortKey],
        dictionary: Dictionary,
        batch_size: int = MAX_BATCH,
        pool: Optional[BatchPool] = None,
    ):
        self.child = child
        self.keys = list(keys)
        self.dictionary = dictionary
        self.batch_size = batch_size
        self.pool = pool
        self._src: Optional[MaterializedSource] = None
        super().__init__("OrderBy", ",".join(f"?v{k.var}" for k in keys))

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _ensure(self) -> MaterializedSource:
        if self._src is None:
            vars_, cols = materialize(self.child)
            # lexsort: last key = primary
            sort_cols = []
            for k in reversed(self.keys):
                codes = cols[vars_.index(k.var)]
                vals = self.dictionary.numeric_of(codes)
                nan = np.isnan(vals)
                # numeric first (by value), then non-numeric by code
                primary = np.where(nan, np.inf, vals)
                tiebreak = np.where(nan, codes, 0)
                if not k.ascending:
                    primary = np.where(nan, np.inf, -vals)
                    tiebreak = np.where(nan, -codes.astype(np.int64), 0)
                sort_cols.extend([tiebreak, primary])
            order = np.lexsort(sort_cols) if sort_cols else np.arange(cols.shape[1])
            self._src = MaterializedSource(
                vars_, cols[:, order], None, self.batch_size,
                name="OrderBuffer", pool=self.pool,
            )
        return self._src

    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def _reset(self) -> None:
        self.child.reset()
        self._src = None
