"""Radix-partitioned vectorized hash join (DESIGN.md §11).

The general join for unsorted inputs: the build side is materialized once
and laid out by the hash_build kernel — rows bucketed by multiplicative-
hash partition id (the radix_partition kernel), key-sorted within each
partition — while the probe side streams through untouched, one vectorized
hash_probe dispatch per batch locating every probe key's contiguous match
run. Emission then reuses the exact merge-join Build machinery: every
probe row is a length-1 left range expanded against its run (join_expand)
and materialized through the fused gather_emit kernel into pool-recycled
buffers, so probe-side order is preserved and the probe side is never
sorted or materialized. This is what replaces the planner's double-PSort +
MergeJoin plan for unsorted inputs (§11 strategy table).

Join keys: every shared variable. One shared variable hashes its raw code
column (NULL_ID == -1 is an ordinary value that equals itself — the same
NULL semantics as MergeJoin and the row engine, pinned by the parity
sweeps). Multiple shared variables pack through vecops.pack_group_keys
with spans fixed from the build side (one sentinel slot per column so
out-of-range probe values can never falsely match) into an int64 split
into an (hi, lo) int32 pair for the kernels; if the span product overflows
62 bits, the join hashes the primary variable and verifies the rest
through gather_emit equality pairs.

Modes: inner, left_outer (OPTIONAL — incl. the LeftJoin *condition*, where
a probe row whose matches all fail the expression still emits NULL-
extended), semi, and anti on one machinery. An empty key tuple is the
degenerate constant-key join: inner == cross product, left_outer == the
NULL-extending cross that fixes disjoint OPTIONAL, anti == the
"remove everything iff the build has any row" shape that NOT EXISTS
needs when it shares no variables with the outer group.
"""

from __future__ import annotations

from time import perf_counter
from typing import List, Optional, Tuple

import numpy as np

from repro.core import vecops
from repro.core.adaptive import AdaptiveBatchSizer
from repro.core.batch import NULL_ID, BatchPool, ColumnBatch, bucket_for
from repro.core.expressions import eval_expr_mask
from repro.core.exprs import eval_program_mask
from repro.core.operators.base import BatchOperator
from repro.core.operators.sort import materialize
from repro.core.partition import (
    PartitionedRelation,
    next_pow2,
    partition_ids_multi,
    split_block,
)
from repro.kernels import ops as KOPS

# target rows per partition: partitions around this size keep the within-
# partition binary search shallow while the partition count stays small
# enough for the histogram kernel's one-hot reduction
_PART_TARGET = 4096
_MAX_PARTS = 1024

# grace mode (DESIGN.md §15): default top-level fan-out when the planner
# directed a grace build without sizing it, sub-fan-out per recursive
# re-partition of a skewed bucket, and the recursion depth cap (4 hash
# levels with distinct multipliers; a bucket still over budget at level 3
# is one hot key and builds resident regardless)
_GRACE_DEFAULT_PARTS = 32
_GRACE_SUB_PARTS = 8
_GRACE_MAX_LEVEL = 3
_GRACE_PROBE_CHUNK = 4096


def _n_parts_for(n_build: int) -> int:
    p = 1
    while p * _PART_TARGET < n_build and p < _MAX_PARTS:
        p *= 2
    return p


class HashJoin(BatchOperator):
    def __init__(
        self,
        probe: BatchOperator,
        build: BatchOperator,
        keys: Tuple[int, ...],
        mode: str = "inner",
        post_filter=None,  # LeftJoin condition (OPTIONAL {...} FILTER)
        dictionary=None,
        sizer: Optional[AdaptiveBatchSizer] = None,
        pool: Optional[BatchPool] = None,
        post_program=None,  # compiled ExprProgram for post_filter (planner)
        backend: Optional[str] = None,  # kernel backend override (tests)
        n_parts: Optional[int] = None,
        memory_budget: Optional[int] = None,  # bytes; None = resident only
        spill_dir: Optional[str] = None,
        grace: Optional[bool] = None,  # True = planner-directed grace build
        grace_parts: int = 0,  # planner-chosen top-level fan-out (0 = auto)
    ) -> None:
        assert mode in ("inner", "left_outer", "semi", "anti")
        self.probe = probe
        self.build = build
        self.keys = tuple(keys)
        self.mode = mode
        self.post_filter = post_filter
        self.dictionary = dictionary
        if post_program is False:  # planner: known uncompilable, no retry
            post_program = None
        elif post_program is None and post_filter is not None and dictionary is not None:
            from repro.core.operators.simple import _resolve_program

            post_program = _resolve_program(post_filter, dictionary, None, "mask")
        self.post_program = post_program
        self.sizer = sizer or AdaptiveBatchSizer(initial=256)
        self.pool = pool
        self.backend = backend
        self._n_parts_cfg = n_parts
        self.memory_budget = memory_budget
        self.spill_dir = spill_dir
        self.grace = grace
        self.grace_parts = grace_parts

        pv, bv = tuple(probe.var_ids()), tuple(build.var_ids())
        self._pv, self._bv = pv, bv
        shared = tuple(x for x in pv if x in bv)
        assert all(k in shared for k in self.keys), (self.keys, shared)
        # shared vars outside the hash key are verified per emitted row via
        # the fused gather_emit equality pairs (like MergeJoin secondaries)
        self._extra_shared = tuple(x for x in shared if x not in self.keys)
        if mode in ("semi", "anti"):
            self._build_out: Tuple[int, ...] = ()
        else:
            self._build_out = tuple(x for x in bv if x not in pv)
        self._out_vars = pv + self._build_out
        self._rsel = tuple(bv.index(x) for x in self._build_out)

        # build-side state (filled by _ensure_built)
        self._built = False
        self._probe_cache: dict = {}
        self._bcols: Optional[np.ndarray] = None  # partition-grouped layout
        self._n_build = 0
        self._n_parts = 1
        self._part_starts: Optional[np.ndarray] = None
        self._spid: Optional[np.ndarray] = None
        self._skh: Optional[np.ndarray] = None
        self._skl: Optional[np.ndarray] = None
        self._spans: Optional[List[int]] = None  # fixed multi-key pack spans
        self._hash_vars: Tuple[int, ...] = self.keys  # may shrink on overflow
        self._pair_vars: Tuple[int, ...] = self._extra_shared

        # grace-mode state (DESIGN.md §15): both sides fanned out once by
        # partition_ids_multi, then joined one partition at a time with the
        # resident radix machinery above
        self._grace_active = False
        self._build_rel: Optional[PartitionedRelation] = None
        self._probe_rel: Optional[PartitionedRelation] = None
        self._probe_partitioned = False
        # work stack of (build_block, probe_block, level) from recursive
        # re-partitioning of skewed buckets; consumed before fresh take()s
        self._grace_stack: List[Tuple[np.ndarray, np.ndarray, int]] = []
        self._next_gp = 0
        self._gp_cols: Optional[np.ndarray] = None  # current probe block
        self._gp_off = 0

        # probe-side continuation state
        self._pending: Optional[Tuple] = None
        # (cb, matched) for left_outer runs that need per-row match tracking
        self._track: Optional[Tuple] = None
        self._leftovers: List[np.ndarray] = []  # (n_pv, n) unmatched rows
        # skip() floor: a parent may gallop us past `target` while pending
        # expansions still hold rows >= target — those must survive, so the
        # floor masks emitted rows below it instead of dropping the batch
        self._skip_floor: Optional[Tuple[int, int]] = None
        super().__init__("HashJoin", f"({','.join(f'?v{k}' for k in keys)}) mode={mode}")

    # -- metadata ---------------------------------------------------------------

    def var_ids(self) -> Tuple[int, ...]:
        return self._out_vars

    def sorted_by(self) -> Optional[int]:
        # probe order is preserved: expansions walk probe rows in order and
        # plain left_outer NULL rows are emitted in place. Tracked
        # left_outer (join condition / pair fallback) queues its NULL rows
        # after the batch's expansions, breaking the interleave. Grace mode
        # re-orders the probe side by partition, so it preserves nothing.
        if self._grace_active or self.grace:
            return None
        if self.mode == "left_outer" and self._needs_tracking():
            return None
        return self.probe.sorted_by()

    def children(self) -> List[BatchOperator]:
        return [self.probe, self.build]

    def _needs_tracking(self) -> bool:
        return self.mode == "left_outer" and (
            self.post_filter is not None or bool(self._pair_vars)
        )

    # -- build phase -------------------------------------------------------------

    def _ensure_built(self) -> None:
        if self._built:
            return
        t0 = perf_counter()
        if self.grace and self.keys:
            # planner-directed grace build: stream the build child straight
            # into the partitioned relation — it is never fully resident
            self._grace_build_stream()
            self._built = True
            self.stats.extra["hash_build_ms"] = round(
                (perf_counter() - t0) * 1e3, 3)
            return
        bvars, bcols = materialize(self.build)
        self._bv = bvars
        self._rsel = tuple(bvars.index(x) for x in self._build_out)
        n = int(bcols.shape[1])
        self._n_build = n
        if not self.keys:
            self._bcols = bcols
            self._built = True
            self.stats.extra["hash_build_rows"] = n
            self.stats.extra["hash_build_ms"] = round(
                (perf_counter() - t0) * 1e3, 3)
            return
        if (
            self.memory_budget is not None
            and bcols.nbytes > self.memory_budget
            and self.probe.sorted_by() is None
        ):
            # runtime resident->grace switch: the planner sized this build
            # as resident but actuals blew the budget. Only taken when no
            # ancestor relies on probe order (unsorted probe), since grace
            # re-orders emission by partition.
            self._grace_switch_from_block(bcols)
            self._built = True
            self.stats.extra["hash_build_rows"] = n
            self.stats.extra["hash_build_ms"] = round(
                (perf_counter() - t0) * 1e3, 3)
            return
        self._build_resident(bcols)
        self._built = True
        self.stats.extra["hash_build_rows"] = n
        self.stats.extra["hash_partitions"] = self._n_parts
        self.stats.extra["hash_build_ms"] = round((perf_counter() - t0) * 1e3, 3)

    def _build_resident(self, bcols: np.ndarray) -> None:
        """Radix-build one in-memory block (full build side, or one grace
        partition at a time). Resets the span/pair layout per block: a
        multi-key span overflow in one grace partition must not leak its
        primary-only fallback into the next."""
        n = int(bcols.shape[1])
        self._n_build = n
        kcols = bcols[[self._bv.index(k) for k in self.keys]]
        self._spans = None
        self._hash_vars = self.keys
        self._pair_vars = self._extra_shared
        if len(self.keys) > 1:
            # one sentinel slot per column (max+3) so clamped out-of-range
            # probe values can never collide with a real build key
            spans = [int(c.max(initial=-1)) + 3 for c in kcols]
            packed = vecops.pack_group_keys(kcols, spans=spans)
            if packed is None:
                # span overflow: hash the primary key, verify the rest via
                # gather_emit equality pairs
                self._hash_vars = self.keys[:1]
                self._pair_vars = self.keys[1:] + self._extra_shared
                bh, bl = None, np.ascontiguousarray(kcols[0])
            else:
                self._spans = spans
                bh = (packed >> 31).astype(np.int32)
                bl = (packed & 0x7FFFFFFF).astype(np.int32)
        else:
            bh, bl = None, np.ascontiguousarray(kcols[0])
        self._n_parts = self._n_parts_cfg or _n_parts_for(n)
        order, part_starts = KOPS.hash_build(
            bh, bl, self._n_parts, backend=self.backend
        )
        self._bcols = bcols[:, order]
        self._part_starts = part_starts
        self._spid = np.repeat(
            np.arange(self._n_parts, dtype=np.int32), np.diff(part_starts)
        )
        self._skh = None if bh is None else bh[order]
        self._skl = bl[order]
        self._probe_cache = {}  # per-build composite cache (kernels.ops)

    # -- grace phase (DESIGN.md §15) ---------------------------------------------

    def _grace_fanout(self) -> int:
        g = self.grace_parts or _GRACE_DEFAULT_PARTS
        return max(2, next_pow2(g))

    def _init_rels(self, n_parts: int) -> None:
        half = None if self.memory_budget is None else max(
            self.memory_budget // 2, 1
        )
        self._build_rel = PartitionedRelation(
            len(self._bv), n_parts, self.spill_dir, half, self.pool
        )
        self._probe_rel = PartitionedRelation(
            len(self._pv), n_parts, self.spill_dir, half, self.pool
        )
        self._next_gp = 0
        self._grace_stack = []
        self._gp_cols = None
        self._gp_off = 0
        self._probe_partitioned = False
        self.stats.extra["grace_partitions"] = n_parts
        self.stats.extra.setdefault("repartitions", 0)

    def _grace_build_stream(self) -> None:
        g = self._grace_fanout()
        self._init_rels(g)
        total = 0
        while True:
            b = self.build.next_batch()
            if b is None:
                break
            cb = b.compact()
            n = cb.n_rows
            if n == 0:
                cb.release()
                continue
            pids = partition_ids_multi(
                [cb.column(k) for k in self.keys], g
            )
            cols = np.stack([cb.column(v) for v in self._bv])
            self._build_rel.append(cols, pids)
            total += n
            cb.release()
        self._grace_active = True
        self.stats.extra["hash_build_rows"] = total
        self._refresh_grace_stats()

    def _grace_switch_from_block(self, bcols: np.ndarray) -> None:
        # fan-out sized so an average partition fits in half the budget
        # (the other half is headroom for the probe partitions)
        g = min(
            256,
            max(2, next_pow2(
                -(-int(bcols.nbytes) // max(self.memory_budget // 2, 1))
            )),
        )
        self._init_rels(g)
        pids = partition_ids_multi(
            [bcols[self._bv.index(k)] for k in self.keys], g
        )
        self._build_rel.append(bcols, pids)
        self._grace_active = True
        self.stats.extra["adaptive_switches"] = 1
        self.stats.detail += " grace"
        self._refresh_grace_stats()

    def _grace_partition_probe(self) -> None:
        g = self._build_rel.n_parts
        while True:
            b = self.probe.next_batch()
            if b is None:
                break
            cb = b.compact()
            n = cb.n_rows
            if n == 0:
                cb.release()
                continue
            pids = partition_ids_multi(
                [cb.column(k) for k in self.keys], g
            )
            cols = np.stack([cb.column(v) for v in self._pv])
            self._probe_rel.append(cols, pids)
            cb.release()
        self._probe_partitioned = True
        self._refresh_grace_stats()

    def _refresh_grace_stats(self) -> None:
        sb = sf = 0
        for rel in (self._build_rel, self._probe_rel):
            if rel is not None:
                sb += rel.spill_bytes
                sf += rel.spill_files
        self.stats.extra["spill_bytes"] = sb
        self.stats.extra["spill_files"] = sf

    def _grace_next_probe(self) -> Optional[ColumnBatch]:
        """Probe-side source while grace is active: chunks of the current
        partition's probe block, advancing partitions in between. Returns
        None when exhausted OR when leftovers were queued (the caller's
        loop flushes them before asking again)."""
        if not self._probe_partitioned:
            self._grace_partition_probe()
        while True:
            if self._gp_cols is not None:
                if self._gp_off < self._gp_cols.shape[1]:
                    j = min(
                        self._gp_off + _GRACE_PROBE_CHUNK,
                        self._gp_cols.shape[1],
                    )
                    chunk = self._gp_cols[:, self._gp_off : j]
                    self._gp_off = j
                    return ColumnBatch.from_columns(
                        self._pv,
                        [chunk[i] for i in range(chunk.shape[0])],
                        None,
                        pool=self.pool,
                    )
                self._gp_cols = None
            if self._leftovers:
                return None  # flush NULL-extension leftovers first
            if not self._grace_advance():
                return None

    def _grace_advance(self) -> bool:
        """Move to the next joinable (build, probe) partition pair. Skewed
        buckets over budget re-partition recursively with a fresh hash
        multiplier per level instead of building an over-budget table."""
        while True:
            if self._grace_stack:
                bblock, pblock, level = self._grace_stack.pop()
            elif self._next_gp < self._build_rel.n_parts:
                g = self._next_gp
                self._next_gp += 1
                bblock = self._build_rel.take(g)
                pblock = self._probe_rel.take(g)
                level = 0
                self._refresh_grace_stats()
            else:
                return False
            if pblock.shape[1] == 0:
                continue
            if bblock.shape[1] == 0:
                # probe-only partition: inner/semi emit nothing; anti and
                # left_outer NULL-extend every probe row via the leftovers
                # path (build_out is empty for anti, so it emits as-is)
                if self.mode in ("anti", "left_outer"):
                    self._leftovers.append(np.ascontiguousarray(pblock))
                    return True
                continue
            if (
                self.memory_budget is not None
                and bblock.nbytes > self.memory_budget
                and level < _GRACE_MAX_LEVEL
                and bblock.shape[1] > 1
                and not self._all_keys_equal(bblock)
            ):
                self._grace_repartition(bblock, pblock, level)
                continue
            self._build_resident(bblock)
            self._gp_cols = pblock
            self._gp_off = 0
            return True

    def _all_keys_equal(self, bblock: np.ndarray) -> bool:
        for k in self.keys:
            c = bblock[self._bv.index(k)]
            if c.shape[0] and not (c == c[0]).all():
                return False
        return True

    def _grace_repartition(
        self, bblock: np.ndarray, pblock: np.ndarray, level: int
    ) -> None:
        g2 = _GRACE_SUB_PARTS
        b_pids = partition_ids_multi(
            [bblock[self._bv.index(k)] for k in self.keys], g2, level + 1
        )
        p_pids = partition_ids_multi(
            [pblock[self._pv.index(k)] for k in self.keys], g2, level + 1
        )
        bsubs = dict(split_block(bblock, b_pids, g2))
        psubs = dict(split_block(pblock, p_pids, g2))
        empty_b = np.empty((bblock.shape[0], 0), dtype=np.int32)
        for p, psub in psubs.items():
            self._grace_stack.append(
                (bsubs.get(p, empty_b), psub, level + 1)
            )
        self.stats.extra["repartitions"] = (
            self.stats.extra.get("repartitions", 0) + 1
        )

    def sip_keys(self, var: int) -> np.ndarray:
        """Build-side key column for a SipFilter export (DESIGN.md §12).
        Runs the build phase if needed — safe, because _next() always
        builds before the first probe batch is pulled, so forcing it from
        a probe-side leaf's first batch only moves the same work earlier.
        The partition-grouped reorder doesn't matter: the bloom filter is
        order-insensitive."""
        self._ensure_built()
        self.stats.extra["sip_exports"] = (
            self.stats.extra.get("sip_exports", 0) + 1
        )
        if self._grace_active:
            # partitioned build: concatenate the key column across
            # partitions (load without freeing — the grace drain still
            # needs them). SIP gating means small builds, so this is rare.
            j = self._bv.index(var)
            parts = [
                self._build_rel.load(p)[j]
                for p in range(self._build_rel.n_parts)
            ]
            return np.ascontiguousarray(np.concatenate(parts))
        return np.ascontiguousarray(
            self._bcols[self._bv.index(var), : self._n_build]
        )

    # -- probe phase -------------------------------------------------------------

    def _probe_keys(self, cb: ColumnBatch) -> Tuple[Optional[np.ndarray], np.ndarray]:
        kcols = [cb.column(v) for v in self._hash_vars]
        if self._spans is not None:
            packed = vecops.pack_group_keys(np.stack(kcols), spans=self._spans)
            return (
                (packed >> 31).astype(np.int32),
                (packed & 0x7FFFFFFF).astype(np.int32),
            )
        return None, np.ascontiguousarray(kcols[0], dtype=np.int32)

    def _run_bounds(self, cb: ColumnBatch) -> Tuple[np.ndarray, np.ndarray]:
        """(lo, len) of each probe row's build match run."""
        n = cb.n_rows
        if not self.keys:  # constant-key degenerate join: match everything
            return (
                np.zeros(n, dtype=np.int32),
                np.full(n, self._n_build, dtype=np.int32),
            )
        qh, ql = self._probe_keys(cb)
        t0 = perf_counter()
        lo, hi = KOPS.hash_probe(
            self._spid, self._skh, self._skl, qh, ql,
            self._part_starts, self._n_parts, backend=self.backend,
            cache=self._probe_cache,
        )
        self.stats.extra["hash_probe_ms"] = round(
            self.stats.extra.get("hash_probe_ms", 0.0)
            + (perf_counter() - t0) * 1e3, 3)
        self.stats.extra["hash_probe_rows"] = (
            self.stats.extra.get("hash_probe_rows", 0) + n)
        return lo, (hi - lo).astype(np.int32)

    def _pairs_for(self, cb: ColumnBatch) -> Tuple[Tuple[int, int], ...]:
        return tuple(
            (cb.col_index(v), self._bv.index(v)) for v in self._pair_vars
        )

    def _next(self) -> Optional[ColumnBatch]:
        self._ensure_built()
        cap = bucket_for(self.sizer.on_next())
        while True:
            if self._pending is not None:
                out = self._emit_pending(cap)
                if self._pending is None and self._track is not None:
                    self._finalize_tracked()
                if out is not None and out.n_active:
                    return out
                if out is not None:
                    out.release()
                continue
            if self._leftovers:
                return self._emit_leftovers(cap)
            if self._grace_active:
                pb = self._grace_next_probe()
                if pb is None:
                    if self._leftovers:
                        continue  # loop top flushes them
                    return None
            else:
                pb = self.probe.next_batch()
                if pb is None:
                    return None
            cb = pb.compact()
            if cb.n_rows == 0:
                cb.release()
                continue
            out = self._probe_batch(cb)
            if out is not None:
                if out.n_active:
                    return out
                out.release()

    def _probe_batch(self, cb: ColumnBatch) -> Optional[ColumnBatch]:
        """Consume one compacted probe batch: either a masked filter result
        (semi/anti), a queued pending expansion (inner/left_outer), or
        queued NULL-extension leftovers."""
        n = cb.n_rows
        lo, lens = self._run_bounds(cb)
        pairs = self._pairs_for(cb)

        if self.mode in ("semi", "anti"):
            if pairs:
                return self._pairwise_exists(
                    cb, lo, lens, pairs, want=self.mode == "semi"
                )
            m = np.zeros(cb.capacity, dtype=bool)
            m[:n] = (lens > 0) if self.mode == "semi" else (lens == 0)
            return cb.with_mask(m)

        if self.mode == "inner" or self._needs_tracking():
            keep = np.nonzero(lens > 0)[0].astype(np.int32)
            if self._needs_tracking():
                matched = np.zeros(n, dtype=bool)
                self._track = (cb, matched)
                if len(keep) == 0:
                    self._finalize_tracked()
                    return None
            elif len(keep) == 0:
                cb.release()
                return None
            plens = np.ones(len(keep), dtype=np.int32)
            cum = vecops.group_output_offsets(plens, lens[keep])
            self._pending = (cb, keep, lo[keep], lens[keep], lens[keep],
                             cum, 0, pairs)
            return None

        # plain left_outer: unmatched probe rows become a run of length 1
        # against a virtual NULL build row (ri == -1 in gather_emit)
        eff = np.maximum(lens, 1)
        pstarts = np.arange(n, dtype=np.int32)
        cum = vecops.group_output_offsets(np.ones(n, np.int32), eff)
        self._pending = (cb, pstarts, lo, lens, eff, cum, 0, pairs)
        return None

    _EXISTS_CHUNK = 1 << 16

    def _pairwise_exists(self, cb, lo, lens, pairs, want: bool) -> ColumnBatch:
        """semi/anti with pair-verified keys: a probe row matches iff any
        build row in its run agrees on every pair column. The expansion is
        verified in bounded chunks — a skewed key's run cross product must
        not materialize at once (cf. _emit_pending's cap)."""
        n = cb.n_rows
        matched = np.zeros(n, dtype=bool)
        nz = np.nonzero(lens > 0)[0]
        if len(nz):
            pstarts = nz.astype(np.int32)
            plens = np.ones(len(nz), dtype=np.int32)
            cum = vecops.group_output_offsets(plens, lens[nz])
            total = int(cum[-1])
            done = 0
            while done < total:
                count = min(self._EXISTS_CHUNK, total - done)
                li, ri = KOPS.join_expand(
                    pstarts, plens, lo[nz], lens[nz], cum, done, count
                )
                _, ok = KOPS.gather_emit(
                    cb.columns, self._bcols, li, ri, (), (), pairs
                )
                if ok.any():
                    np.logical_or.at(matched, li[ok], True)
                done += count
        m = np.zeros(cb.capacity, dtype=bool)
        m[:n] = matched if want else ~matched
        return cb.with_mask(m)

    # -- emission ----------------------------------------------------------------

    def _emit_pending(self, cap: int) -> Optional[ColumnBatch]:
        cb, pstarts, lo, lens, eff, cum, emitted, pairs = self._pending
        total = int(cum[-1])
        count = min(cap, total - emitted)
        li, ri = KOPS.join_expand(
            pstarts, np.ones(len(pstarts), dtype=np.int32), lo, eff,
            cum, emitted, count,
        )
        base = emitted
        emitted += count
        done = emitted >= total
        self._pending = None if done else (
            cb, pstarts, lo, lens, eff, cum, emitted, pairs
        )
        if self.mode == "left_outer" and self._track is None:
            # virtual NULL runs: unmatched probe rows gather build index -1
            group_of = np.searchsorted(
                cum, base + np.arange(count), side="right") - 1
            ri = np.where(lens[group_of] == 0, np.int32(-1), ri)

        lsel = tuple(cb.col_index(v) for v in self._pv)
        b = ColumnBatch.alloc(
            self._out_vars, bucket_for(max(count, 1)), self.pool,
            self.sorted_by(),
        )
        _, mask = KOPS.gather_emit(
            cb.columns, self._bcols, li, ri, lsel, self._rsel, pairs,
            out=b.columns,
        )
        b.n_rows = count
        if count < b.capacity:
            b.columns[:, count:] = NULL_ID
        b.mask[:count] = mask
        if self.pool is not None:
            self.pool.bytes_copied += len(self._out_vars) * count * 4
        if self.post_filter is not None:
            if self.post_program is not None:
                b = b.with_mask(
                    eval_program_mask(self.post_program, b, self.dictionary)
                )
            else:
                b = b.with_mask(
                    eval_expr_mask(self.post_filter, b, self.dictionary)
                )
        if self._track is not None:
            surv = b.mask[:count]
            if surv.any():
                self._track[1][li[surv]] = True
        if self._skip_floor is not None:
            # applied AFTER match tracking: a skipped row still counts as
            # matched for left_outer bookkeeping, it just isn't re-emitted
            fv, ft = self._skip_floor
            floor = np.ones(b.capacity, dtype=bool)
            floor[:count] = cb.columns[cb.col_index(fv), li] >= ft
            b = b.with_mask(floor)
        if done and self._track is None:
            cb.release()
        return b

    def _finalize_tracked(self) -> None:
        cb, matched = self._track
        self._track = None
        um = np.nonzero(~matched)[0].astype(np.int32)
        if len(um):
            idx = [cb.col_index(v) for v in self._pv]
            self._leftovers.append(np.asarray(cb.columns[idx][:, um]))
        cb.release()

    def _emit_leftovers(self, cap: int) -> ColumnBatch:
        rows = self._leftovers.pop(0)
        if self._skip_floor is not None:
            fv, ft = self._skip_floor
            rows = rows[:, rows[self._pv.index(fv)] >= ft]
        n = rows.shape[1]
        if n > cap:
            self._leftovers.insert(0, rows[:, cap:])
            rows = rows[:, :cap]
            n = cap
        out_cols = [rows[i] for i in range(rows.shape[0])]
        for _ in self._build_out:
            out_cols.append(np.full(n, NULL_ID, dtype=np.int32))
        return ColumnBatch.from_columns(
            self._out_vars, out_cols, None, pool=self.pool
        )

    # -- control ----------------------------------------------------------------

    def _drop_pending(self) -> None:
        if self._pending is not None:
            if self._track is None:
                self._pending[0].release()
            self._pending = None
        if self._track is not None:
            self._track[0].release()
            self._track = None
        self._leftovers.clear()

    def _skip(self, var: int, target: int) -> None:
        # pending expansions and leftovers may still hold rows >= target:
        # narrow them with a floor mask at emission instead of dropping
        if self._skip_floor is not None and self._skip_floor[0] == var:
            target = max(target, self._skip_floor[1])
        self._skip_floor = (var, target)
        self.probe.skip(var, target)

    def _close(self) -> None:
        # grace spill teardown — reached via executor finally even when a
        # mid-query exception aborts the drain (ISSUE-9 leak fix)
        for rel in (self._build_rel, self._probe_rel):
            if rel is not None:
                rel.close()

    def _reset(self) -> None:
        self._drop_pending()
        self._skip_floor = None
        self.probe.reset()
        self.build.reset()
        self._built = False
        self._probe_cache = {}
        self._bcols = None
        self._part_starts = None
        self._spid = self._skh = self._skl = None
        self._spans = None
        self._hash_vars = self.keys
        self._pair_vars = self._extra_shared
        self._close()
        self._grace_active = False
        self._build_rel = self._probe_rel = None
        self._probe_partitioned = False
        self._grace_stack = []
        self._next_gp = 0
        self._gp_cols = None
        self._gp_off = 0
