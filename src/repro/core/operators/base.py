"""Vector Volcano operator API (paper §3.1).

Each operator pulls *batches* from its children via ``next_batch()`` and may
reposition sorted children via ``skip()`` — BARQ's distinguishing addition to
the vectorized pull model. ``reset()`` restarts iteration (used by the legacy
bind join and by tests). Operators expose per-operator runtime statistics so
the profiler can print Listing-1/3/5-style plans.
"""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

from repro.core import batch as _batch
from repro.core.batch import ColumnBatch


class OpStats:
    __slots__ = (
        "name",
        "detail",
        "results",
        "batches",
        "next_calls",
        "skip_calls",
        "reset_calls",
        "wall_time",
        "rows_scanned",
        "est_rows",
        "est_source",
        "node_fp",
        "extra",
    )

    def __init__(self, name: str, detail: str = "") -> None:
        self.name = name
        self.detail = detail
        self.results = 0  # output rows (active)
        self.batches = 0  # output batches
        self.next_calls = 0  # next() calls received
        self.skip_calls = 0  # skip() calls received
        self.reset_calls = 0
        self.wall_time = 0.0  # seconds spent inside this operator (self+children)
        self.rows_scanned = 0  # storage rows read (scans only; overfetch metric)
        # planner cardinality estimate for this operator's Phys node, or
        # None when lowering had no estimate (EXPLAIN ANALYZE input)
        self.est_rows: Optional[float] = None
        # where the estimate came from: "stats" (cost model) or "feedback"
        # (observed-cardinality override, DESIGN.md §14)
        self.est_source: str = "stats"
        # the Phys node's stable fingerprint (planner), or None for
        # programmatically built trees / adapters — the key the executor
        # records actual cardinalities under
        self.node_fp: Optional[str] = None
        # operator-specific counters (e.g. PathExpand frontier rounds /
        # dedup ratio); the profiler prints and aggregates them generically
        self.extra: dict = {}


class BatchOperator:
    """Base class: pull-based batch iteration with skip support."""

    def __init__(self, name: str, detail: str = "") -> None:
        self.stats = OpStats(name, detail)

    # -- public API (wrapped for stats) --------------------------------------

    def next_batch(self) -> Optional[ColumnBatch]:
        self.stats.next_calls += 1
        san = _batch._SANITIZER
        if san is not None:
            # pool-sanitizer attribution scope (DESIGN.md §16): batches
            # acquired while this operator runs carry its name, so
            # leak / use-after-release reports name the allocating operator
            san.push_op(self.stats.name)
        t0 = time.perf_counter()
        try:
            b = self._next()
        finally:
            self.stats.wall_time += time.perf_counter() - t0
            if san is not None:
                san.pop_op()
        if b is not None:
            self.stats.batches += 1
            self.stats.results += b.n_active
        return b

    def skip(self, var: int, target: int) -> None:
        """Reposition so subsequent batches only contain rows with
        column ``var`` >= ``target``. Only valid if ``sorted_by() == var``."""
        self.stats.skip_calls += 1
        t0 = time.perf_counter()
        self._skip(var, target)
        self.stats.wall_time += time.perf_counter() - t0

    def reset(self) -> None:
        self.stats.reset_calls += 1
        self._reset()

    # -- metadata -------------------------------------------------------------

    def var_ids(self) -> Tuple[int, ...]:
        raise NotImplementedError

    def sorted_by(self) -> Optional[int]:
        return None

    def supports_skip(self) -> bool:
        return self.sorted_by() is not None

    def can_skip(self, var: Optional[int]) -> bool:
        """True iff skip(var, ...) is valid on this operator — queryable so
        callers (SIP range narrowing, join galloping) choose mask-mode
        fallbacks instead of relying on ValueError control flow."""
        return var is not None and self.sorted_by() == var

    def children(self) -> List["BatchOperator"]:
        return []

    # -- resource teardown -----------------------------------------------------

    def close(self) -> None:
        """Release external resources (spill files, mapped buffers) for this
        operator and its whole subtree. Idempotent; stats survive — the
        executor calls this in a ``finally`` so EXPLAIN ANALYZE still works
        after a mid-query exception (the ISSUE-9 spill-leak fix)."""
        close_tree(self)

    def _close(self) -> None:
        """Per-operator teardown hook — release disk/buffers only."""

    # -- implementation hooks ---------------------------------------------------

    def _next(self) -> Optional[ColumnBatch]:
        raise NotImplementedError

    def _skip(self, var: int, target: int) -> None:
        raise NotImplementedError(f"{self.stats.name} does not support skip()")

    def _reset(self) -> None:
        raise NotImplementedError

    # -- convenience --------------------------------------------------------------

    def drain(self) -> List[ColumnBatch]:
        out = []
        while True:
            b = self.next_batch()
            if b is None:
                return out
            if b.n_active:
                out.append(b)


class CloseError(RuntimeError):
    """One or more ``_close`` hooks raised during tree teardown. The walk
    still visited every operator first; ``errors`` carries each failure as
    (operator name, exception)."""

    def __init__(self, errors) -> None:
        self.errors = list(errors)
        detail = "; ".join(
            f"{name}: {type(e).__name__}: {e}" for name, e in self.errors
        )
        super().__init__(
            f"{len(self.errors)} operator close() failure(s): {detail}"
        )


def close_tree(op) -> None:
    """Walk an operator tree (batch or row; duck-typed on ``children``) and
    invoke every ``_close`` hook. An exception from one hook doesn't stop
    the walk — a failed unlink must not leak the rest of the tree's spill
    files — but it is not swallowed either: after every operator has been
    visited, the collected failures re-raise as one ``CloseError``."""
    stack = [op]
    errors = []
    while stack:
        o = stack.pop()
        cl = getattr(o, "_close", None)
        if cl is not None:
            try:
                cl()
            except Exception as e:  # keep closing siblings first
                errors.append((getattr(o, "stats", o).name
                               if hasattr(o, "stats") else type(o).__name__, e))
        ch = getattr(o, "children", None)
        if ch is not None:
            try:
                stack.extend(ch())
            except Exception as e:
                errors.append((type(o).__name__, e))
    if errors:
        raise CloseError(errors)
