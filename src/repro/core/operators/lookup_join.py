"""Lookup join: sort-based replacement for the classical hash join.

For plans where one input is not sorted by the join variable, relational
engines use a hash join. On TPU, random-access hash probes are HBM-latency-
bound gathers; the idiomatic equivalent is *sort-based*: materialize the
build side once, sort it by the key (code order), and probe every stream
batch with a vectorized binary search (kernels sorted_search). The probe
then reuses the exact merge-join Build machinery — every probe row is a
length-1 left range joined against the matching build run. Emission runs
through the fused gather_emit kernel (probe gather + build gather +
NULL-extension of unmatched left_outer rows + secondary-key equality in
one dispatch) into pool-recycled buffers. Output preserves probe-side
order. See DESIGN.md §2 (hardware-adaptation table) and §2.3.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import vecops
from repro.core.batch import NULL_ID, BatchPool, ColumnBatch, bucket_for
from repro.core.operators.base import BatchOperator
from repro.core.operators.sort import materialize
from repro.kernels import ops as KOPS


class LookupJoin(BatchOperator):
    def __init__(
        self,
        probe: BatchOperator,
        build: BatchOperator,
        join_var: int,
        mode: str = "inner",
        pool: Optional[BatchPool] = None,
    ) -> None:
        assert mode in ("inner", "left_outer", "semi", "anti")
        self.probe = probe
        self.build = build
        self.v = join_var
        self.mode = mode
        self.pool = pool
        pv, bv = tuple(probe.var_ids()), tuple(build.var_ids())
        assert join_var in pv and join_var in bv
        self.secondary = tuple(x for x in pv if x in bv and x != join_var)
        # left_outer + secondary keys needs per-group survivor tracking —
        # the planner routes that case to MergeJoin (which implements it)
        assert not (mode == "left_outer" and self.secondary), (
            "LookupJoin left_outer with secondary join keys unsupported; use MergeJoin"
        )
        if mode in ("semi", "anti"):
            self._build_out: Tuple[int, ...] = ()
        else:
            self._build_out = tuple(x for x in bv if x not in pv)
        self._out_vars = pv + self._build_out
        self._built = False
        self._bcols: Optional[np.ndarray] = None
        self._bkeys: Optional[np.ndarray] = None
        self._bvars = bv
        # static gather_emit plan
        self._lsel = tuple(range(len(pv)))
        self._rsel = tuple(bv.index(x) for x in self._build_out)
        self._pairs = tuple((pv.index(sv), bv.index(sv)) for sv in self.secondary)
        # continuation of an oversized expansion
        self._pending: Optional[Tuple] = None
        super().__init__("LookupJoin", f"(?v{join_var}) mode={mode}")

    def var_ids(self) -> Tuple[int, ...]:
        return self._out_vars

    def sorted_by(self) -> Optional[int]:
        return self.probe.sorted_by()

    def children(self) -> List[BatchOperator]:
        return [self.probe, self.build]

    def _ensure_built(self) -> None:
        if self._built:
            return
        bvars, bcols = materialize(self.build)
        key = bcols[bvars.index(self.v)]
        order = np.argsort(key, kind="stable")
        self._bcols = bcols[:, order]
        self._bkeys = key[order]
        self._bvars = bvars
        self._built = True

    def _next(self) -> Optional[ColumnBatch]:
        self._ensure_built()
        cap = bucket_for(4096)
        while True:
            if self._pending is not None:
                out = self._emit_pending(cap)
                if out is not None:
                    if out.n_active:
                        return out
                    out.release()  # fully masked-out block: recycle
                continue
            pb = self.probe.next_batch()
            if pb is None:
                return None
            cb = pb.compact()
            if cb.n_rows == 0:
                cb.release()
                continue
            keys = cb.column(self.v)
            lo = vecops.sorted_search(self._bkeys, keys, "left")
            hi = vecops.sorted_search(self._bkeys, keys, "right")
            lens = (hi - lo).astype(np.int32)
            if self.mode == "semi":
                if self.secondary:
                    out = self._secondary_exists(cb, lo, lens, want_match=True)
                else:
                    m = np.zeros(cb.capacity, dtype=bool)
                    m[: cb.n_rows] = lens > 0
                    out = cb.with_mask(m)
                if out.n_active:
                    return out
                out.release()
                continue
            if self.mode == "anti" and not self.secondary:
                m = np.zeros(cb.capacity, dtype=bool)
                m[: cb.n_rows] = lens == 0
                out = cb.with_mask(m)
                if out.n_active:
                    return out
                out.release()
                continue
            if self.mode == "anti":
                out = self._secondary_exists(cb, lo, lens, want_match=False)
                if out.n_active:
                    return out
                out.release()
                continue
            # inner / left_outer: groups = (probe row i, build run lo[i:hi[i]))
            pstarts = np.arange(cb.n_rows, dtype=np.int32)
            plens = np.ones(cb.n_rows, dtype=np.int32)
            if self.mode == "left_outer":
                # unmatched probe rows emit one NULL-extended row: model them
                # as a run of length 1 against a virtual NULL build row
                eff_lens = np.maximum(lens, 1)
            else:
                keep = lens > 0
                pstarts, plens = pstarts[keep], plens[keep]
                lo, lens = lo[keep], lens[keep]
                eff_lens = lens
            if len(pstarts) == 0:
                cb.release()
                continue
            cum = vecops.group_output_offsets(plens, eff_lens)
            self._pending = (cb, pstarts, lo, lens, eff_lens, cum, 0)

    def _secondary_exists(self, cb, lo, lens, want_match: bool) -> ColumnBatch:
        """semi/anti with secondary keys: a probe row matches if any build
        row in its run agrees on all secondary keys — the fused equality
        mask of gather_emit, reduced per probe row."""
        n = cb.n_rows
        matched = np.zeros(n, dtype=bool)
        nz = np.nonzero(lens > 0)[0]
        if len(nz):
            pstarts = nz.astype(np.int32)
            plens = np.ones(len(nz), dtype=np.int32)
            cum = vecops.group_output_offsets(plens, lens[nz])
            total = int(cum[-1])
            li, ri = KOPS.join_expand(pstarts, plens, lo[nz], lens[nz], cum, 0, total)
            _, ok = KOPS.gather_emit(
                cb.columns, self._bcols, li, ri, (), (), self._pairs
            )
            if ok.any():
                np.logical_or.at(matched, li[ok], True)
        m = np.zeros(cb.capacity, dtype=bool)
        m[:n] = matched if want_match else ~matched
        return cb.with_mask(m)

    def _emit_pending(self, cap: int) -> Optional[ColumnBatch]:
        cb, pstarts, lo, lens, eff_lens, cum, emitted = self._pending
        total = int(cum[-1])
        count = min(cap, total - emitted)
        li, ri = KOPS.join_expand(
            pstarts, np.ones(len(pstarts), dtype=np.int32), lo, eff_lens, cum, emitted, count
        )
        base = emitted
        emitted += count
        done = emitted >= total
        self._pending = None if done else (
            cb, pstarts, lo, lens, eff_lens, cum, emitted
        )
        if self.mode == "left_outer":
            # rows from virtual NULL runs (unmatched probe rows): mark their
            # build index -1 so gather_emit NULL-extends them
            group_of = np.searchsorted(cum, base + np.arange(count), side="right") - 1
            ri = np.where(lens[group_of] == 0, np.int32(-1), ri)
        b = ColumnBatch.alloc(
            self._out_vars, bucket_for(max(count, 1)), self.pool, self.sorted_by()
        )
        _, mask = KOPS.gather_emit(
            cb.columns, self._bcols, li, ri,
            self._lsel, self._rsel, self._pairs, out=b.columns,
        )
        b.n_rows = count
        if count < b.capacity:
            b.columns[:, count:] = NULL_ID
        b.mask[:count] = mask
        if self.pool is not None:
            self.pool.bytes_copied += len(self._out_vars) * count * 4
        if done:
            cb.release()
        return b

    def _skip(self, var: int, target: int) -> None:
        if self._pending is not None:
            self._pending[0].release()
        self._pending = None
        self.probe.skip(var, target)

    def _reset(self) -> None:
        self.probe.reset()
        self.build.reset()
        if self._pending is not None:
            self._pending[0].release()
        self._pending = None
        self._built = False

    def _close(self) -> None:
        # early teardown mid-expansion: the pending probe batch still owns
        # pooled buffers
        if self._pending is not None:
            self._pending[0].release()
            self._pending = None
