"""PathExpand: the batch property-path operator (DESIGN.md §8).

Evaluates one path pattern through the vectorized frontier engine and
streams the materialized pair relation out as pooled, subject-sorted
column batches — a pipeline breaker like Sort (the closure must complete
before sorted emission), honoring the release()/drain() buffer-ownership
protocol.

Seed-side choice: a bound subject seeds forward BFS from that single node;
a bound object seeds BFS over the flipped relation (bound-object
expansion) and swaps the pairs back; with both endpoints free the engine
enumerates every source. Frontier metrics (rounds, peak frontier size,
dedup ratio) land in OpStats.extra for the profiler.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.algebra import K, Slot, V
from repro.core.batch import BatchPool, ColumnBatch
from repro.core.operators.base import BatchOperator
from repro.core.sip import SipFilter
from repro.core.paths.engine import PathEngine, PathResult
from repro.core.paths.expr import PathExpr, path_repr
from repro.core.storage import QuadStore


class PathExpand(BatchOperator):
    def __init__(
        self,
        store: QuadStore,
        expr: PathExpr,
        s_slot: Slot,
        o_slot: Slot,
        batch_size: int = 4096,
        pool: Optional[BatchPool] = None,
        backend: Optional[str] = None,
        sip_filters: Sequence[SipFilter] = (),
    ) -> None:
        self.store = store
        self.expr = expr
        self.s_slot, self.o_slot = s_slot, o_slot
        # SIP prefilters (DESIGN.md §12), mask-mode only: the closure is
        # materialized wholesale by the frontier engine, so range seeks buy
        # nothing here — but masking emitted pairs still prunes the join's
        # probe stream
        self.sip_filters = list(sip_filters)
        self.batch_size = batch_size
        self.pool = pool
        self.engine = PathEngine(store, pool, backend)
        self._result: Optional[PathResult] = None
        self._offset = 0

        self._var_ids: Tuple[int, ...]
        if isinstance(s_slot, V) and isinstance(o_slot, V):
            self._var_ids = (
                (s_slot.id,) if s_slot.id == o_slot.id else (s_slot.id, o_slot.id)
            )
            self._sorted_var: Optional[int] = s_slot.id
            self.seed_side = "subject"
        elif isinstance(s_slot, K) and isinstance(o_slot, V):
            self._var_ids = (o_slot.id,)
            self._sorted_var = o_slot.id
            self.seed_side = "subject"  # forward BFS from the bound subject
        elif isinstance(s_slot, V) and isinstance(o_slot, K):
            self._var_ids = (s_slot.id,)
            self._sorted_var = s_slot.id
            self.seed_side = "object"  # reverse BFS from the bound object
        else:
            self._var_ids = ()
            self._sorted_var = None
            self.seed_side = "subject"  # both bound: forward from subject
        super().__init__("PathExpand", self._describe())

    def _describe(self) -> str:
        def slot(sl: Slot) -> str:
            return f"?v{sl.id}" if isinstance(sl, V) else str(sl.term)

        return (
            f"({slot(self.s_slot)}, {path_repr(self.expr)}, "
            f"{slot(self.o_slot)}) [seed={self.seed_side}]"
        )

    # -- operator API -------------------------------------------------------

    def var_ids(self) -> Tuple[int, ...]:
        return self._var_ids

    def sorted_by(self) -> Optional[int]:
        return self._sorted_var

    def children(self) -> List[BatchOperator]:
        return []

    # -- evaluation ---------------------------------------------------------

    def _seed(self, sl: Slot) -> Optional[np.ndarray]:
        tid = self.store.dict.lookup(sl.term)
        if tid is None:
            return None  # unknown constant: empty result
        return np.asarray([tid], dtype=np.int32)

    def _evaluate(self) -> PathResult:
        empty = PathResult(
            np.zeros(0, dtype=np.int32), np.zeros(0, dtype=np.int32)
        )
        s_bound = isinstance(self.s_slot, K)
        o_bound = isinstance(self.o_slot, K)
        if s_bound:
            seeds = self._seed(self.s_slot)
            if seeds is None:
                return empty
            res = self.engine.evaluate(self.expr, seeds=seeds)
        elif o_bound:
            seeds = self._seed(self.o_slot)
            if seeds is None:
                return empty
            res = self.engine.evaluate(self.expr, seeds=seeds, reverse=True)
        else:
            res = self.engine.evaluate(self.expr)
        if s_bound and o_bound:  # both-bound: existence check
            oid = self.store.dict.lookup(self.o_slot.term)
            if oid is None:
                return empty
            keep = res.dst == int(oid)
            res = PathResult(res.src[keep], res.dst[keep])
        if len(self._var_ids) == 1 and not (s_bound or o_bound):
            # ?x path ?x — keep only cyclic pairs
            keep = res.src == res.dst
            res = PathResult(res.src[keep], res.dst[keep])
        self.stats.rows_scanned += len(res)
        self.stats.extra.update(self.engine.counters.as_dict())
        self.stats.extra["dedup_ratio"] = round(
            self.engine.counters.dedup_ratio, 3
        )
        return res

    def _primary(self) -> np.ndarray:
        """The column the emitted batches are sorted by."""
        assert self._result is not None
        if isinstance(self.s_slot, V):
            return self._result.src
        return self._result.dst

    def _next(self) -> Optional[ColumnBatch]:
        if self._result is None:
            self._result = self._evaluate()
        res = self._result
        if not self._var_ids:  # both endpoints bound: 0/1 row existence
            if self._offset or not len(res):
                return None
            self._offset = len(res) or 1
            b = ColumnBatch.alloc((), 32, self.pool)
            b.mask[0] = True
            b.n_rows = 1
            return b
        if self._offset >= len(res):
            return None
        n = min(self.batch_size, len(res) - self._offset)
        sl = slice(self._offset, self._offset + n)
        self._offset += n
        if len(self._var_ids) == 2:
            cols = [res.src[sl], res.dst[sl]]
        elif isinstance(self.s_slot, V) and isinstance(self.o_slot, V):
            cols = [res.src[sl]]  # ?x path ?x (src == dst)
        elif isinstance(self.s_slot, V):
            cols = [res.src[sl]]
        else:
            cols = [res.dst[sl]]
        b = ColumnBatch.from_columns(
            self._var_ids, cols, self._sorted_var, pool=self.pool
        )
        for f in self.sip_filters:
            if f.var not in self._var_ids:
                continue
            m = f.mask(b.columns[b.col_index(f.var), : b.n_rows])
            if m is None:
                continue
            full = np.ones(b.capacity, dtype=bool)
            full[: b.n_rows] = m
            b = b.with_mask(full)
        if self.sip_filters:
            self.stats.extra["sip_pruned_rows"] = sum(
                f.rows_pruned for f in self.sip_filters
            )
            self.stats.extra["sip_probe_dispatches"] = sum(
                f.probe_dispatches for f in self.sip_filters
            )
        return b

    def can_skip(self, var: Optional[int]) -> bool:
        return var is not None and var == self._sorted_var

    def _skip(self, var: int, target: int) -> None:
        if not self.can_skip(var):
            raise ValueError("skip on unsorted variable")
        if self._result is None:
            self._result = self._evaluate()
        primary = self._primary()
        pos = int(np.searchsorted(primary, target, side="left"))
        if pos > self._offset:
            self._offset = pos

    def _reset(self) -> None:
        self._offset = 0
