"""Batch↔row adapters (paper §4.2 Interoperability).

BatchToRow lets per-row (legacy) operators consume BARQ output: copy-free —
a batch is immediately iterable as an array of rows via the selection
vector. RowToBatch lets BARQ operators consume legacy output, typically at
a pipeline-breaking point. Both preserve sort order and forward skip().
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.batch import NULL_ID, BatchPool, ColumnBatch, bucket_for
from repro.core.legacy.operators import Row, RowOperator
from repro.core.operators.base import BatchOperator


class BatchToRow(RowOperator):
    def __init__(self, child: BatchOperator):
        self.child = child
        self._batch: Optional[ColumnBatch] = None
        self._sel: Optional[np.ndarray] = None
        self._i = 0
        super().__init__("BatchToRow", "")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.child.sorted_by()

    def children(self):  # mixed-tree profiler support
        return [self.child]

    def _next(self) -> Optional[Row]:
        while True:
            if self._batch is not None and self._i < len(self._sel):
                r = self._sel[self._i]
                self._i += 1
                b = self._batch
                return {
                    v: int(b.columns[ci, r])
                    for ci, v in enumerate(b.var_ids)
                    if b.columns[ci, r] != NULL_ID
                }
            if self._batch is not None:
                self._batch.release()  # rows were copied out as dicts
            self._batch = self.child.next_batch()
            if self._batch is None:
                return None
            self._sel = self._batch.selection_vector()
            self._i = 0

    def _skip(self, var: int, target: int) -> None:
        # drop buffered rows below target, then skip the child
        if self._batch is not None and self._sel is not None:
            ci = self._batch.col_index(var)
            col = self._batch.columns[ci, self._sel[self._i :]]
            self._i += int(np.searchsorted(col, target, side="left"))
            if self._i >= len(self._sel):
                self._batch.release()
                self._batch = None
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()
        if self._batch is not None:
            self._batch.release()
        self._batch = None
        self._i = 0

    def _close(self) -> None:
        # a query that stops early (LIMIT, error) tears down mid-batch:
        # hand the buffered batch back to the arena
        if self._batch is not None:
            self._batch.release()
            self._batch = None


class RowToBatch(BatchOperator):
    def __init__(
        self,
        child: RowOperator,
        batch_size: int = 1024,
        pool: Optional[BatchPool] = None,
    ):
        self.child = child
        self.batch_size = batch_size
        self.pool = pool
        super().__init__("RowToBatch", "")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.child.sorted_by()

    def children(self) -> List[BatchOperator]:
        return [self.child]  # type: ignore[list-item]

    def _next(self) -> Optional[ColumnBatch]:
        vars_ = tuple(self.child.var_ids())
        cap = bucket_for(self.batch_size)
        b = ColumnBatch.alloc(vars_, cap, self.pool, self.child.sorted_by())
        cols = b.columns
        n = 0
        while n < self.batch_size:
            r = self.child.next_row()
            if r is None:
                break
            for ci, v in enumerate(vars_):
                cols[ci, n] = r.get(v, int(NULL_ID))
            n += 1
        if n == 0:
            b.release()
            return None
        if n < cap:
            cols[:, n:] = NULL_ID
        b.mask[:n] = True
        b.n_rows = n
        b.sorted_by = self.child.sorted_by()
        return b

    def _skip(self, var: int, target: int) -> None:
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()
