"""Filter, Project, Extend (BIND), Slice, Union — vectorized unary/binary ops.

FILTER is the showcase selection-vector consumer (paper §3.1): it reads only
the referenced columns, evaluates the expression vectorized, and *updates the
mask* — no copying, batches stay alive longer. All-inactive batches are
discarded (the batch-pool case the paper mentions) by fetching the next one.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.algebra import Expr
from repro.core.batch import NULL_ID, BatchPool, ColumnBatch, concat_batches
from repro.core.dictionary import Dictionary
from repro.core.expressions import eval_expr_mask, eval_expr_values
from repro.core.exprs import (
    ExprCompileError,
    ProgramTimer,
    compile_expr,
    eval_program_mask,
    eval_program_values,
)
from repro.core.operators.base import BatchOperator

_UNSET = object()


def _resolve_program(expr: Expr, dictionary: Optional[Dictionary],
                     program, mode: str):
    """Program handed down by the planner, or a lazy compile for
    hand-built operator trees; None -> interpreted tree-walk fallback."""
    if program is not None:
        return program
    if dictionary is None:
        return None
    try:
        return compile_expr(expr, dictionary, mode)
    except ExprCompileError:
        return None


class FilterOp(BatchOperator):
    """FILTER through the expression VM: one fused program evaluation per
    batch updates the mask in place. Per-program op counts and dispatch
    timings surface through OpStats.extra (profiler / collect_stats)."""

    def __init__(
        self,
        child: BatchOperator,
        expr: Expr,
        dictionary: Optional[Dictionary],
        program=_UNSET,
        name: str = "Filter",  # "Having" for the post-grouping stage
    ):
        self.child = child
        self.expr = expr
        self.dictionary = dictionary
        self.program = (
            _resolve_program(expr, dictionary, None, "mask")
            if program is _UNSET
            else program
        )
        self._timer = ProgramTimer()
        super().__init__(name, "" if self.program is None else "[vm]")
        if self.program is not None:
            self.stats.extra["expr_ops"] = len(self.program.instrs)

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.child.sorted_by()  # filtering preserves order

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _mask(self, b: ColumnBatch) -> np.ndarray:
        if self.program is None:
            return eval_expr_mask(self.expr, b, self.dictionary)
        with self._timer:
            m = eval_program_mask(self.program, b, self.dictionary)
        self.stats.extra["expr_dispatches"] = self._timer.dispatches
        self.stats.extra["expr_eval_ms"] = round(self._timer.wall_s * 1e3, 3)
        return m

    def _next(self) -> Optional[ColumnBatch]:
        while True:
            b = self.child.next_batch()
            if b is None:
                return None
            b = b.with_mask(self._mask(b))
            if b.n_active:
                return b
            b.release()  # all rows inactive: recycle batch, keep pulling

    def _skip(self, var: int, target: int) -> None:
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()


class ProjectOp(BatchOperator):
    def __init__(
        self,
        child: BatchOperator,
        keep: Tuple[int, ...],
        pool: Optional[BatchPool] = None,
    ):
        self.child = child
        self.keep = tuple(keep)
        self.pool = pool
        super().__init__("Project", f"{len(keep)} vars")

    def var_ids(self) -> Tuple[int, ...]:
        return self.keep

    def sorted_by(self) -> Optional[int]:
        sb = self.child.sorted_by()
        return sb if sb in self.keep else None

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _next(self) -> Optional[ColumnBatch]:
        b = self.child.next_batch()
        if b is None:
            return None
        if self.pool is None:
            return b.project(self.keep)
        # pooled path: copy the kept columns into a recycled buffer and
        # give the source buffers back
        idx = [b.col_index(v) for v in self.keep]
        sb = b.sorted_by if b.sorted_by in self.keep else None
        out = ColumnBatch.alloc(self.keep, b.capacity, self.pool, sb)
        out.columns[...] = b.columns[idx]
        out.mask[...] = b.mask
        out.n_rows = b.n_rows
        self.pool.bytes_copied += out.columns.nbytes
        b.release()
        return out

    def _skip(self, var: int, target: int) -> None:
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()


class ExtendOp(BatchOperator):
    """BIND (expr AS ?v): computes the value expression vectorized over the
    batch, dictionary-encodes the distinct results, appends a column."""

    def __init__(
        self,
        child: BatchOperator,
        var: int,
        expr: Expr,
        dictionary: Dictionary,
        pool: Optional[BatchPool] = None,
        program=_UNSET,
    ):
        self.child = child
        self.var = var
        self.expr = expr
        self.dictionary = dictionary
        self.pool = pool
        self.program = (
            _resolve_program(expr, dictionary, None, "value")
            if program is _UNSET
            else program
        )
        self._timer = ProgramTimer()
        super().__init__("Bind", f"?v{var}" + ("" if self.program is None else " [vm]"))
        if self.program is not None:
            self.stats.extra["expr_ops"] = len(self.program.instrs)

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids() + (self.var,)

    def sorted_by(self) -> Optional[int]:
        return self.child.sorted_by()

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _next(self) -> Optional[ColumnBatch]:
        b = self.child.next_batch()
        if b is None:
            return None
        if self.program is None:
            vals, ok = eval_expr_values(self.expr, b, self.dictionary)
        else:
            with self._timer:
                vals, ok = eval_program_values(self.program, b, self.dictionary)
            self.stats.extra["expr_dispatches"] = self._timer.dispatches
            self.stats.extra["expr_eval_ms"] = round(self._timer.wall_s * 1e3, 3)
        codes = np.full(b.capacity, NULL_ID, dtype=np.int32)
        n = b.n_rows
        # encode the few distinct computed values, map back vectorized
        uniq, inv = np.unique(vals[:n][ok[:n]], return_inverse=True)
        uniq_ids = np.asarray(
            [self.dictionary.encode(float(u)) for u in uniq], dtype=np.int32
        )
        tmp = np.full(n, NULL_ID, dtype=np.int32)
        if len(uniq):
            tmp[ok[:n]] = uniq_ids[inv]
        codes[:n] = tmp
        out = ColumnBatch.alloc(self.var_ids(), b.capacity, self.pool, b.sorted_by)
        out.columns[:-1] = b.columns
        out.columns[-1] = codes
        out.mask[...] = b.mask
        out.n_rows = b.n_rows
        if self.pool is not None:
            self.pool.bytes_copied += out.columns.nbytes
        b.release()
        return out

    def _reset(self) -> None:
        self.child.reset()


class SliceOp(BatchOperator):
    """LIMIT/OFFSET over active rows."""

    def __init__(self, child: BatchOperator, limit: Optional[int], offset: int = 0):
        self.child = child
        self.limit = limit
        self.offset = offset
        self._seen = 0
        self._emitted = 0
        super().__init__("Slice", f"limit={limit} offset={offset}")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def sorted_by(self) -> Optional[int]:
        return self.child.sorted_by()

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _next(self) -> Optional[ColumnBatch]:
        while True:
            if self.limit is not None and self._emitted >= self.limit:
                return None
            b = self.child.next_batch()
            if b is None:
                return None
            sel = b.selection_vector()
            n = len(sel)
            lo = max(0, self.offset - self._seen)
            self._seen += n
            keep = sel[lo:]
            if self.limit is not None:
                keep = keep[: self.limit - self._emitted]
            if len(keep) == 0:
                b.release()
                continue
            m = np.zeros(b.capacity, dtype=bool)
            m[keep] = True
            self._emitted += len(keep)
            # keep ⊆ active rows, so narrowing the mask is equivalent to
            # replacing it (and moves pooled-buffer ownership along)
            return b.with_mask(m)

    def _reset(self) -> None:
        self.child.reset()
        self._seen = 0
        self._emitted = 0


class UnionOp(BatchOperator):
    def __init__(
        self,
        left: BatchOperator,
        right: BatchOperator,
        pool: Optional[BatchPool] = None,
    ):
        self.left = left
        self.right = right
        self.pool = pool
        lv = tuple(left.var_ids())
        self._vars = lv + tuple(v for v in right.var_ids() if v not in lv)
        self._on_right = False
        super().__init__("Union", "")

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def children(self) -> List[BatchOperator]:
        return [self.left, self.right]

    def _next(self) -> Optional[ColumnBatch]:
        while True:
            src = self.right if self._on_right else self.left
            b = src.next_batch()
            if b is None:
                if self._on_right:
                    return None
                self._on_right = True
                continue
            if set(b.var_ids) == set(self._vars):
                # cheap path: same schema, reorder columns only
                order = [b.col_index(v) for v in self._vars]
                m = b.mask if b.pool is None else b.mask.copy()
                out = ColumnBatch(self._vars, b.columns[order], m, b.n_rows, None)
                b.release()  # row fancy-indexing copied the columns
                return out
            return concat_batches([b], self._vars, pool=self.pool, release_inputs=True)

    def _reset(self) -> None:
        self.left.reset()
        self.right.reset()
        self._on_right = False
