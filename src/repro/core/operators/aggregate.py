"""Vectorized grouping engine (paper §3.3, DESIGN.md §10).

StreamingGroupBy handles the paper's optimized case: a single group variable
with input sorted by it. Standard aggregates (count/sum/min/max/avg) are
associative, so every batch reduces to per-run partials with ONE
``kernels.ops.segment_reduce`` dispatch per required statistic (numpy
oracle / jnp ref / Pallas segmented scan) and a single scalar carry for the
run spanning the batch boundary — no Python-level per-run loops. DISTINCT
aggregates sort each batch by (group, code) and dedup through the
``frontier_dedup`` kernel (adjacent-unique over sorted pairs); only the
boundary run keeps an explicit code set, merged by sorted union.

Semantics (shared with the legacy row engine and pinned by
tests/test_aggregate.py):

  * COUNT counts *bound* terms (numeric or not); every other aggregate
    restricts to numeric terms via the dictionary side-array;
  * DISTINCT dedups bound codes before the aggregate function is applied —
    ``SUM(DISTINCT ?x)`` sums the distinct values, it is not a count;
  * MIN/MAX/AVG over an empty (or all-unbound / all-non-numeric) group
    leave the output variable unbound instead of encoding NaN.

Backend note: numpy is the default backend and the float64 oracle; the
jnp/Pallas segmented scans accumulate in float32, so their SUM/AVG partials
are exact only for f32-representable magnitudes (integer sums below 2^24 —
the same caveat as the expression VM, DESIGN.md §9.5). COUNT(DISTINCT *)
is rejected at parse time rather than silently approximated (it would need
whole-solution dedup, not a per-column code set).

SortGroupBy is the general fallback (multi-var or unsorted input): it
drains only the needed columns from pooled batches, sorts ONCE by a packed
int64 composite key, assigns dense group ids, and streams the sorted runs
through StreamingGroupBy — sort-based grouping, the TPU-idiomatic
replacement for vectorized hash grouping (DESIGN.md §2).

StreamingDistinct implements DISTINCT-via-skip() for sorted inputs: after
seeing key k it *skips* the child to k+1, scrolling over duplicates in
storage (paper: 'highly efficient for queries with many duplicates').
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import vecops
from repro.core.algebra import AggSpec
from repro.core.batch import MAX_BATCH, NULL_ID, BatchPool, ColumnBatch
from repro.core.dictionary import Dictionary
from repro.core.operators.base import BatchOperator
from repro.core.operators.sort import MaterializedSource, materialize
from repro.core.partition import PartitionedRelation, partition_ids_multi
from repro.kernels import ops

_EMPTY_I32 = np.zeros(0, dtype=np.int32)

# per-run statistics each (func, distinct) aggregate consumes; 'cnt' is the
# run length, 'bnd'/'nn' count bound / numeric rows, 'sum'/'min'/'max' fold
# numeric values, and the d-prefixed stats fold over the per-run distinct
# bound codes (DESIGN.md §10)
_NEEDS: Dict[Tuple[str, bool], Tuple[str, ...]] = {
    ("count*", False): ("cnt",),
    ("count*", True): ("cnt",),  # hand-built plans only: parser rejects it
    ("count", False): ("bnd",),
    ("count", True): ("dbnd",),
    ("sum", False): ("sum",),
    ("sum", True): ("dsum",),
    ("min", False): ("min", "nn"),
    ("min", True): ("min", "nn"),  # distinct never changes an extremum
    ("max", False): ("max", "nn"),
    ("max", True): ("max", "nn"),
    ("avg", False): ("sum", "nn"),
    ("avg", True): ("dsum", "dnn"),
}

_DISTINCT_STATS = ("dbnd", "dnn", "dsum")
_SCALAR_INIT = {
    "cnt": 0.0, "bnd": 0.0, "nn": 0.0, "sum": 0.0,
    "min": np.inf, "max": -np.inf,
}


def _agg_needs(a: AggSpec) -> Tuple[str, ...]:
    func = "count*" if a.var is None else a.func
    return _NEEDS[(func, a.distinct)]


@dataclasses.dataclass
class _Carry:
    """Scalar partials for the group run spanning the batch boundary.

    Associative stats merge as scalars; the DISTINCT stats cannot (codes in
    the next batch may repeat earlier ones), so for DISTINCT count/sum/avg
    the carry collects each batch's sorted-unique bound-code slice and
    dedups ONCE when the run provably closes — appending chunks keeps a
    group spanning B batches O(total codes), not O(B * total)."""

    key: Optional[int] = None
    stats: Optional[List[Dict[str, float]]] = None  # per-agg scalar partials
    dcodes: Optional[Dict[int, List[np.ndarray]]] = None  # per-agg code chunks


class StreamingGroupBy(BatchOperator):
    """GROUP BY <one var> with aggregates over input sorted by that var.
    group_var None => global aggregation (single group)."""

    def __init__(
        self,
        child: BatchOperator,
        group_var: Optional[int],
        aggs: Sequence[AggSpec],
        dictionary: Dictionary,
        batch_size: int = MAX_BATCH,
        pool: Optional[BatchPool] = None,
        backend: Optional[str] = None,
    ):
        if group_var is not None:
            assert child.sorted_by() == group_var, "input must be sorted by group var"
        self.child = child
        self.g = group_var
        self.aggs = list(aggs)
        self.dictionary = dictionary
        self.batch_size = batch_size
        self.pool = pool
        self.backend = backend
        self._needs = [_agg_needs(a) for a in self.aggs]
        self._dset_aggs = tuple(
            ai for ai, need in enumerate(self._needs)
            if any(s in _DISTINCT_STATS for s in need)
        )
        self._out_keys: List[np.ndarray] = []
        self._out_vals: List[List[np.ndarray]] = [[] for _ in self.aggs]
        self._carry = _Carry()
        self._enc_keys: Optional[np.ndarray] = None
        self._enc_cols: List[np.ndarray] = []
        self._emitted = 0
        self._drained = False
        self._sr_calls = 0
        self._sr_ms = 0.0
        self._dd_calls = 0
        self._dd_ms = 0.0
        self._runs = 0
        super().__init__(
            "Group",
            f"by=?v{group_var} " + ",".join(f"{a.func}->?v{a.out}" for a in aggs),
        )

    def var_ids(self) -> Tuple[int, ...]:
        base = (self.g,) if self.g is not None else ()
        return base + tuple(a.out for a in self.aggs)

    def sorted_by(self) -> Optional[int]:
        return self.g

    def children(self) -> List[BatchOperator]:
        return [self.child]

    # -- kernel dispatch ---------------------------------------------------------

    def _reduce(self, keys: np.ndarray, values: Optional[np.ndarray],
                func: str, seg=None) -> np.ndarray:
        t0 = time.perf_counter()
        _, out = ops.segment_reduce(
            keys, values, func, backend=self.backend, seg=seg
        )
        self._sr_ms += time.perf_counter() - t0
        self._sr_calls += 1
        return np.asarray(out, dtype=np.float64)

    # -- aggregation -------------------------------------------------------------

    def _consume_all(self) -> None:
        while True:
            b = self.child.next_batch()
            if b is None:
                break
            cb = b.compact()
            if cb.n_rows == 0:
                cb.release()
                continue
            keys = (
                cb.column(self.g)
                if self.g is not None
                else np.zeros(cb.n_rows, dtype=np.int32)
            )
            self._consume_batch(keys, cb)
            cb.release()  # per-run partials copied into outputs / carry
        self._close_carry()
        if self.g is None and not self._out_keys:
            # global aggregate over empty input still yields one row
            # (COUNT = 0, SUM = 0; MIN/MAX/AVG stay unbound)
            self._carry = self._open_carry(0)
            self._close_carry()
        self.stats.extra["group_runs"] = self._runs
        self.stats.extra["segment_reduce"] = self._sr_calls
        self.stats.extra["segment_reduce_ms"] = round(self._sr_ms * 1e3, 3)
        if self._dd_calls:
            self.stats.extra["distinct_dedup"] = self._dd_calls
            self.stats.extra["distinct_dedup_ms"] = round(self._dd_ms * 1e3, 3)
        self._drained = True

    def _batch_stats(self, keys: np.ndarray, cb: ColumnBatch, n_runs: int,
                     seg=None):
        """Per-run partial arrays for every aggregate, one segment_reduce
        dispatch per distinct (var, stat) pair — all sharing the batch's
        precomputed ``seg`` boundaries (the within-run sort of the distinct
        path permutes rows only inside runs, so the boundaries coincide).
        Returns (stats, dinfo): stats[ai][stat] is a (n_runs,) float64
        array; dinfo[ai] is the (sorted_codes, keep_mask) pair used to
        slice the unique bound codes of a boundary run out of the sorted
        batch."""
        col_cache: Dict[int, Dict[str, np.ndarray]] = {}
        dsort_cache: Dict[int, Tuple[np.ndarray, ...]] = {}
        job_cache: Dict[Tuple[int, str], np.ndarray] = {}

        def cols_of(var: int) -> Dict[str, np.ndarray]:
            c = col_cache.get(var)
            if c is None:
                codes = cb.column(var)
                vals = self.dictionary.numeric_of(codes)
                c = {"codes": codes, "vals": vals, "valid": ~np.isnan(vals)}
                col_cache[var] = c
            return c

        def dsort_of(var: int) -> Tuple[np.ndarray, ...]:
            d = dsort_cache.get(var)
            if d is None:
                c = cols_of(var)
                order = np.lexsort((c["codes"], keys))
                skeys = keys[order]
                scodes = c["codes"][order]
                # adjacent-unique over sorted (group, code) pairs — the
                # frontier_dedup kernel with an empty visited set; codes are
                # shifted by one so NULL (-1) stays in the kernel's
                # non-negative pair domain
                t0 = time.perf_counter()
                uniq = np.asarray(ops.frontier_dedup(
                    skeys, scodes + np.int32(1), _EMPTY_I32, _EMPTY_I32,
                    backend=self.backend,
                ), dtype=bool)
                self._dd_ms += time.perf_counter() - t0
                self._dd_calls += 1
                keep = uniq & (scodes >= 0)  # first occurrence AND bound
                svals = c["vals"][order]
                d = (skeys, scodes, svals, keep)
                dsort_cache[var] = d
            return d

        def job(var: Optional[int], stat: str) -> np.ndarray:
            key = (-1 if var is None else var, stat)
            out = job_cache.get(key)
            if out is not None:
                return out
            if stat == "cnt":
                out = self._reduce(keys, None, "count", seg)
            elif stat in ("bnd", "nn", "sum", "min", "max"):
                c = cols_of(var)
                if stat == "bnd":
                    out = self._reduce(
                        keys, (c["codes"] >= 0).astype(np.float64), "sum", seg)
                elif stat == "nn":
                    out = self._reduce(keys, c["valid"].astype(np.float64), "sum", seg)
                elif stat == "sum":
                    out = self._reduce(
                        keys, np.where(c["valid"], c["vals"], 0.0), "sum", seg)
                elif stat == "min":
                    out = self._reduce(
                        keys, np.where(c["valid"], c["vals"], np.inf), "min", seg)
                else:
                    out = self._reduce(
                        keys, np.where(c["valid"], c["vals"], -np.inf), "max", seg)
            else:  # distinct stats run over the (group, code)-sorted batch
                skeys, _, svals, keep = dsort_of(var)
                if stat == "dbnd":
                    out = self._reduce(skeys, keep.astype(np.float64), "sum", seg)
                elif stat == "dnn":
                    dv = keep & ~np.isnan(svals)
                    out = self._reduce(skeys, dv.astype(np.float64), "sum", seg)
                else:  # dsum
                    dv = keep & ~np.isnan(svals)
                    out = self._reduce(skeys, np.where(dv, svals, 0.0), "sum", seg)
            assert len(out) == n_runs
            job_cache[key] = out
            return out

        stats = [
            {stat: job(a.var, stat) for stat in need}
            for a, need in zip(self.aggs, self._needs)
        ]
        dinfo = {
            ai: (dsort_of(self.aggs[ai].var)[1], dsort_of(self.aggs[ai].var)[3])
            for ai in self._dset_aggs
        }
        return stats, dinfo

    def _consume_batch(self, keys: np.ndarray, cb: ColumnBatch) -> None:
        run_keys, starts, lengths = vecops.run_boundaries(keys)
        n_runs = len(run_keys)
        if n_runs == 0:
            return
        self._runs += n_runs
        # one boundary derivation per batch, shared by every reduction
        seg_ids = (
            np.repeat(np.arange(n_runs), lengths)
            if any(a.var is not None for a in self.aggs)
            else None
        )
        stats, dinfo = self._batch_stats(
            keys, cb, n_runs, seg=(run_keys, lengths, seg_ids)
        )
        i0 = 0
        if self._carry.key is not None:
            if int(run_keys[0]) == self._carry.key:
                # first run continues the open group: fold its partials in
                self._merge_run(stats, dinfo, 0, starts, lengths)
                i0 = 1
                if n_runs > 1:
                    self._close_carry()
            else:
                self._close_carry()
        last = n_runs - 1
        if last > i0:
            # every interior run is provably complete: finalize vectorized
            sl = slice(i0, last)
            self._out_keys.append(run_keys[sl].copy())
            for ai, a in enumerate(self.aggs):
                part = {k: v[sl] for k, v in stats[ai].items()}
                self._out_vals[ai].append(self._final(a, part))
        if last >= i0:
            # the last run may span the batch boundary: it becomes the carry
            self._carry = self._open_carry(int(run_keys[last]))
            self._merge_run(stats, dinfo, last, starts, lengths)

    def _open_carry(self, key: int) -> _Carry:
        return _Carry(
            key=key,
            stats=[
                {s: _SCALAR_INIT[s] for s in need if s not in _DISTINCT_STATS}
                for need in self._needs
            ],
            dcodes={},
        )

    def _merge_run(self, stats, dinfo, r: int, starts, lengths) -> None:
        c = self._carry
        for ai in range(len(self.aggs)):
            st = c.stats[ai]
            for k, arr in stats[ai].items():
                if k in _DISTINCT_STATS:
                    continue  # folded through the code set below
                if k == "min":
                    st["min"] = min(st["min"], float(arr[r]))
                elif k == "max":
                    st["max"] = max(st["max"], float(arr[r]))
                else:
                    st[k] += float(arr[r])
            if ai in dinfo:
                scodes, keep = dinfo[ai]
                s, e = int(starts[r]), int(starts[r] + lengths[r])
                run_codes = scodes[s:e][keep[s:e]]  # sorted unique by constr.
                c.dcodes.setdefault(ai, []).append(run_codes.copy())

    def _close_carry(self) -> None:
        c = self._carry
        if c.key is None:
            return
        self._out_keys.append(np.asarray([c.key], dtype=np.int32))
        for ai, a in enumerate(self.aggs):
            st = dict(c.stats[ai])
            if ai in self._dset_aggs:
                chunks = c.dcodes.get(ai)
                codes = (
                    np.unique(np.concatenate(chunks)) if chunks else _EMPTY_I32
                )
                if not len(codes):
                    st.update(dbnd=0.0, dnn=0.0, dsum=0.0)
                else:
                    vals = self.dictionary.numeric_of(codes)
                    ok = ~np.isnan(vals)
                    st.update(
                        dbnd=float(len(codes)),
                        dnn=float(ok.sum()),
                        dsum=float(vals[ok].sum()) if ok.any() else 0.0,
                    )
            part = {k: np.asarray([v], dtype=np.float64) for k, v in st.items()}
            self._out_vals[ai].append(self._final(a, part))
        self._carry = _Carry()

    @staticmethod
    def _final(a: AggSpec, st: Dict[str, np.ndarray]) -> np.ndarray:
        """Vectorized finalization: per-run float64 results, NaN marking an
        UNBOUND output (mapped to NULL_ID at encode time, never a NaN term)."""
        if a.var is None:
            return st["cnt"]
        if a.func == "count":
            return st["dbnd"] if a.distinct else st["bnd"]
        if a.func == "sum":
            return st["dsum"] if a.distinct else st["sum"]
        if a.func == "min":
            return np.where(st["nn"] > 0, st["min"], np.nan)
        if a.func == "max":
            return np.where(st["nn"] > 0, st["max"], np.nan)
        if a.func == "avg":
            num = st["dsum"] if a.distinct else st["sum"]
            den = st["dnn"] if a.distinct else st["nn"]
            return np.where(den > 0, num / np.maximum(den, 1.0), np.nan)
        raise ValueError(a.func)

    # -- emission ----------------------------------------------------------------

    def _encode(self, vals: np.ndarray) -> np.ndarray:
        """Bulk result encoding: one dictionary.encode per *distinct* value
        (not per group), mapped back with one vectorized take; NaN rows
        (unbound aggregates) become NULL_ID."""
        codes = np.full(len(vals), NULL_ID, dtype=np.int32)
        ok = ~np.isnan(vals)
        if ok.any():
            uniq, inv = np.unique(vals[ok], return_inverse=True)
            ids = np.asarray(
                [
                    self.dictionary.encode(
                        int(u) if float(u).is_integer() else float(u)
                    )
                    for u in uniq
                ],
                dtype=np.int32,
            )
            codes[ok] = ids[inv]
        return codes

    def _next(self) -> Optional[ColumnBatch]:
        if not self._drained:
            self._consume_all()
        if self._enc_keys is None:
            self._enc_keys = (
                np.concatenate(self._out_keys) if self._out_keys else _EMPTY_I32
            )
            self._enc_cols = [
                self._encode(
                    np.concatenate(v) if v else np.zeros(0, dtype=np.float64)
                )
                for v in self._out_vals
            ]
        n = len(self._enc_keys)
        if self._emitted >= n:
            return None
        hi = min(self._emitted + self.batch_size, n)
        sl = slice(self._emitted, hi)
        cols = [self._enc_keys[sl]] if self.g is not None else []
        cols.extend(c[sl] for c in self._enc_cols)
        self._emitted = hi
        return ColumnBatch.from_columns(self.var_ids(), cols, self.g, pool=self.pool)

    def _reset(self) -> None:
        self.child.reset()
        self._out_keys = []
        self._out_vals = [[] for _ in self.aggs]
        self._carry = _Carry()
        self._enc_keys = None
        self._enc_cols = []
        self._emitted = 0
        self._drained = False
        self._sr_calls = 0
        self._sr_ms = 0.0
        self._dd_calls = 0
        self._dd_ms = 0.0
        self._runs = 0


# synthetic variable id for the packed composite group key (never collides
# with parser-assigned ids, which are non-negative)
_GID = -1


class SortGroupBy(BatchOperator):
    """General GROUP BY (multi-var or unsorted input): drain only the
    needed columns from pooled batches, sort ONCE by a packed int64
    composite key (vecops.pack_group_keys), assign dense group ids, and
    stream the sorted runs through StreamingGroupBy."""

    def __init__(
        self,
        child: BatchOperator,
        group_vars: Sequence[int],
        aggs: Sequence[AggSpec],
        dictionary: Dictionary,
        batch_size: int = MAX_BATCH,
        pool: Optional[BatchPool] = None,
        backend: Optional[str] = None,
    ):
        self.child = child
        self.group_vars = tuple(group_vars)
        self.aggs = list(aggs)
        self.dictionary = dictionary
        self.batch_size = batch_size
        self.pool = pool
        self.backend = backend
        self._src: Optional[BatchOperator] = None
        self._stream: Optional[StreamingGroupBy] = None
        super().__init__("Group", f"by={self.group_vars} (sort-based)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.group_vars + tuple(a.out for a in self.aggs)

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _drain_needed(self, need: Tuple[int, ...]) -> np.ndarray:
        """Materialize only the grouping + aggregate input columns,
        recycling every consumed batch through the pool."""
        blocks = []
        while True:
            b = self.child.next_batch()
            if b is None:
                break
            cb = b.compact()
            if cb.n_rows:
                idx = [cb.col_index(v) for v in need]
                blocks.append(cb.columns[idx, : cb.n_rows])  # fancy-index copy
            cb.release()
        if blocks:
            return np.concatenate(blocks, axis=1)
        return np.zeros((len(need), 0), dtype=np.int32)

    def _need_vars(self) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
        avars = tuple(
            dict.fromkeys(a.var for a in self.aggs if a.var is not None)
        )
        return tuple(dict.fromkeys(self.group_vars + avars)), avars

    def _aggregate_block(
        self, cols: np.ndarray, need: Tuple[int, ...], avars: Tuple[int, ...]
    ) -> np.ndarray:
        """Sort-based aggregation of one in-memory block: sort ONCE by the
        packed composite key, assign dense gids, stream the runs through
        StreamingGroupBy, and translate gids back to group-key values via
        each group's first sorted row. Returns an (n_out_vars, n_groups)
        block. Shared by the whole-input path below and the per-partition
        path (PartitionedGroupBy): group keys never span partitions, so
        per-partition blocks concatenate into the global result."""
        n = cols.shape[1]
        key_rows = cols[: 0] if not self.group_vars else cols[
            [need.index(v) for v in self.group_vars]
        ]
        if self.group_vars and n:
            packed = vecops.pack_group_keys(key_rows)
            order = np.argsort(packed, kind="stable")
            cols = cols[:, order]
            key_rows = cols[[need.index(v) for v in self.group_vars]]
            _, starts, lengths = vecops.run_boundaries(packed[order])
            gid = np.repeat(
                np.arange(len(starts), dtype=np.int32), lengths
            )
        else:
            gid = np.zeros(n, dtype=np.int32)
            starts = np.zeros(1 if n else 0, dtype=np.int64)

        inner = np.concatenate(
            [gid[None, :], cols[[need.index(v) for v in avars]]], axis=0
        ) if avars else gid[None, :]
        inner_src = MaterializedSource(
            (_GID,) + avars, inner, _GID, self.batch_size,
            name="GroupSortBuffer", pool=self.pool,
        )
        self._stream = StreamingGroupBy(
            inner_src, _GID, self.aggs, self.dictionary, self.batch_size,
            backend=self.backend,
        )
        # drain the stream (small: one row per group), then translate the
        # dense gid back to the group-key column values via each group's
        # first sorted row
        svars, scols = materialize(self._stream)
        gids = scols[0]
        first_row = starts[gids] if n else np.zeros(0, dtype=np.int64)
        out_cols = [kr[first_row] for kr in key_rows]
        out_cols.extend(scols[1 + ai] for ai in range(len(self.aggs)))
        for k, v in self._stream.stats.extra.items():
            if k.endswith("_ms") or isinstance(v, (int, float)):
                self.stats.extra[k] = self.stats.extra.get(k, 0) + v
            else:
                self.stats.extra[k] = v
        return (
            np.stack(out_cols, axis=0).astype(np.int32)
            if out_cols
            else np.zeros((0, 0), dtype=np.int32)
        )

    def _ensure(self) -> BatchOperator:
        if self._src is not None:
            return self._src
        need, avars = self._need_vars()
        cols = self._drain_needed(need)
        block = self._aggregate_block(cols, need, avars)
        self._src = MaterializedSource(
            self.var_ids(), block, None, self.batch_size, name="GroupOut",
            pool=self.pool,
        )
        return self._src

    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def _reset(self) -> None:
        self.child.reset()
        self._src = None
        self._stream = None


class StreamingDistinct(BatchOperator):
    """DISTINCT over input sorted by its (single) visible variable, using
    skip() to scroll past duplicates in storage (paper §3.3)."""

    def __init__(self, child: BatchOperator, var: int, use_skip: bool = True):
        assert child.sorted_by() == var
        self.child = child
        self.var = var
        self.use_skip = use_skip and child.supports_skip()
        self._last: Optional[int] = None
        super().__init__("Distinct", f"(?v{var}) streaming")

    def var_ids(self) -> Tuple[int, ...]:
        return (self.var,)

    def sorted_by(self) -> Optional[int]:
        return self.var

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _next(self) -> Optional[ColumnBatch]:
        while True:
            b = self.child.next_batch()
            if b is None:
                return None
            fb = b.compact()
            cb = fb.project((self.var,))
            fb.release()  # project copied the kept column
            if cb.n_rows == 0:
                continue
            keys = cb.column(self.var)
            run_keys, starts, _ = vecops.run_boundaries(keys)
            if self._last is not None:
                keep = run_keys != self._last
                run_keys, starts = run_keys[keep], starts[keep]
            if len(run_keys) == 0:
                continue
            self._last = int(run_keys[-1])
            if self.use_skip:
                # scroll the child past the last seen value
                self.child.skip(self.var, self._last + 1)
            return ColumnBatch.from_columns((self.var,), [run_keys], self.var)

    def _skip(self, var: int, target: int) -> None:
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()
        self._last = None


class SortDistinct(BatchOperator):
    """General DISTINCT: materialize + unique rows (sort-based)."""

    def __init__(self, child: BatchOperator, batch_size: int = MAX_BATCH):
        self.child = child
        self.batch_size = batch_size
        self._src: Optional[MaterializedSource] = None
        super().__init__("Distinct", "(sort-based)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _ensure(self) -> MaterializedSource:
        if self._src is None:
            vars_, cols = materialize(self.child)
            uniq = np.unique(cols.T, axis=0).T if cols.shape[1] else cols
            sb = vars_[0] if len(vars_) == 1 and uniq.shape[1] else None
            self._src = MaterializedSource(
                vars_, uniq.astype(np.int32), sb, self.batch_size, name="DistinctBuffer"
            )
        return self._src

    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def _reset(self) -> None:
        self.child.reset()
        self._src = None


class PartitionedGroupBy(SortGroupBy):
    """GROUP BY over the partitioned substrate (DESIGN.md §15): fan the
    input out by group key into a budget/spill-aware PartitionedRelation,
    then run the sort-based block aggregation one partition at a time.
    Each group's rows land in exactly one partition (same key tuple ->
    same partition id), so per-partition outputs concatenate into the
    global result — the whole input is never sorted or resident at once,
    unlike the parent's single-argsort path."""

    def __init__(
        self,
        child: BatchOperator,
        group_vars: Sequence[int],
        aggs: Sequence[AggSpec],
        dictionary: Dictionary,
        batch_size: int = MAX_BATCH,
        pool: Optional[BatchPool] = None,
        backend: Optional[str] = None,
        memory_budget: Optional[int] = None,
        spill_dir: Optional[str] = None,
        n_parts: int = 16,
    ):
        assert group_vars, "partitioned grouping needs group keys"
        super().__init__(
            child, group_vars, aggs, dictionary, batch_size, pool, backend
        )
        self.memory_budget = memory_budget
        self.spill_dir = spill_dir
        self.n_parts = max(2, n_parts)
        self._rel: Optional[PartitionedRelation] = None
        self.stats.name = "Group"
        self.stats.detail = f"by={self.group_vars} (partitioned)"

    def _partition_input(self, need: Tuple[int, ...]) -> PartitionedRelation:
        rel = PartitionedRelation(
            len(need), self.n_parts, self.spill_dir, self.memory_budget,
            self.pool,
        )
        gidx = [need.index(v) for v in self.group_vars]
        while True:
            b = self.child.next_batch()
            if b is None:
                break
            cb = b.compact()
            if cb.n_rows:
                cols = np.stack([cb.column(v) for v in need])
                rel.append(cols, partition_ids_multi(cols[gidx], self.n_parts))
            cb.release()
        return rel

    def _ensure(self) -> BatchOperator:
        if self._src is not None:
            return self._src
        need, avars = self._need_vars()
        self._rel = self._partition_input(need)
        blocks = []
        for p in range(self.n_parts):
            part = self._rel.take(p)
            if part.shape[1]:
                blocks.append(self._aggregate_block(part, need, avars))
        block = (
            np.concatenate(blocks, axis=1)
            if blocks
            else np.zeros((len(self.var_ids()), 0), dtype=np.int32)
        )
        self.stats.extra["grace_partitions"] = self.n_parts
        self.stats.extra["spill_bytes"] = self._rel.spill_bytes
        self.stats.extra["spill_files"] = self._rel.spill_files
        self._src = MaterializedSource(
            self.var_ids(), block, None, self.batch_size, name="GroupOut",
            pool=self.pool,
        )
        return self._src

    def _close(self) -> None:
        if self._rel is not None:
            self._rel.close()

    def _reset(self) -> None:
        self._close()
        self._rel = None
        super()._reset()


class PartitionedDistinct(BatchOperator):
    """General DISTINCT over the partitioned substrate: fan rows out by
    ALL visible columns, dedup each partition independently (identical
    rows share a partition id by construction), and concatenate. Output
    order is partition-major — never claimed sorted, unlike SortDistinct
    whose np.unique output is globally ordered."""

    def __init__(
        self,
        child: BatchOperator,
        batch_size: int = MAX_BATCH,
        pool: Optional[BatchPool] = None,
        memory_budget: Optional[int] = None,
        spill_dir: Optional[str] = None,
        n_parts: int = 16,
    ):
        self.child = child
        self.batch_size = batch_size
        self.pool = pool
        self.memory_budget = memory_budget
        self.spill_dir = spill_dir
        self.n_parts = max(2, n_parts)
        self._rel: Optional[PartitionedRelation] = None
        self._src: Optional[MaterializedSource] = None
        super().__init__("Distinct", "(partitioned)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _ensure(self) -> MaterializedSource:
        if self._src is not None:
            return self._src
        nv = len(self.var_ids())
        self._rel = PartitionedRelation(
            nv, self.n_parts, self.spill_dir, self.memory_budget, self.pool
        )
        vs = self.var_ids()
        while True:
            b = self.child.next_batch()
            if b is None:
                break
            cb = b.compact()
            if cb.n_rows:
                cols = np.stack([cb.column(v) for v in vs])
                self._rel.append(
                    cols, partition_ids_multi(cols, self.n_parts)
                )
            cb.release()
        blocks = []
        for p in range(self.n_parts):
            part = self._rel.take(p)
            if part.shape[1]:
                blocks.append(np.unique(part.T, axis=0).T)
        uniq = (
            np.concatenate(blocks, axis=1).astype(np.int32)
            if blocks
            else np.zeros((nv, 0), dtype=np.int32)
        )
        self.stats.extra["grace_partitions"] = self.n_parts
        self.stats.extra["spill_bytes"] = self._rel.spill_bytes
        self.stats.extra["spill_files"] = self._rel.spill_files
        self._src = MaterializedSource(
            vs, uniq, None, self.batch_size, name="DistinctBuffer",
            pool=self.pool,
        )
        return self._src

    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def _close(self) -> None:
        if self._rel is not None:
            self._rel.close()

    def _reset(self) -> None:
        self._close()
        self._rel = None
        self.child.reset()
        self._src = None
