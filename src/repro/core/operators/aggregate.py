"""Vectorized aggregation (paper §3.3).

StreamingGroupBy handles the paper's optimized case: a single group variable
with input sorted by it. Standard aggregates (count/sum/min/max/avg) are
associative: each batch reduces to per-run partials (vecops.segment_reduce /
kernels segment_reduce) which merge across batches through a carry for the
run that spans the batch boundary. No hash table is needed — exactly why the
paper ships streaming aggregation first (§3.3: no row-based memory-manager
hash tables involved).

SortGroupBy is the general fallback: materialize, sort by group keys
(sort-based grouping — the TPU-idiomatic replacement for vectorized hash
grouping, DESIGN.md §2), then stream. StreamingDistinct implements
DISTINCT-via-skip() for sorted inputs: after seeing key k it *skips* the
child to k+1, scrolling over duplicates in storage (paper: 'highly
efficient for queries with many duplicates').
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import vecops
from repro.core.algebra import AggSpec
from repro.core.batch import MAX_BATCH, ColumnBatch
from repro.core.dictionary import Dictionary
from repro.core.operators.base import BatchOperator
from repro.core.operators.sort import MaterializedSource, materialize


@dataclasses.dataclass
class _AggState:
    """Carry for the group run spanning the current batch boundary."""

    key: Optional[int] = None
    count: float = 0.0
    sums: Optional[Dict[int, float]] = None  # per-agg partial
    mins: Optional[Dict[int, float]] = None
    maxs: Optional[Dict[int, float]] = None
    counts: Optional[Dict[int, float]] = None  # per-agg non-null counts
    distinct: Optional[Dict[int, set]] = None  # per-agg distinct codes


class StreamingGroupBy(BatchOperator):
    """GROUP BY <one var> with aggregates over input sorted by that var.
    group_var None => global aggregation (single group)."""

    def __init__(
        self,
        child: BatchOperator,
        group_var: Optional[int],
        aggs: Sequence[AggSpec],
        dictionary: Dictionary,
        batch_size: int = MAX_BATCH,
    ):
        if group_var is not None:
            assert child.sorted_by() == group_var, "input must be sorted by group var"
        self.child = child
        self.g = group_var
        self.aggs = list(aggs)
        self.dictionary = dictionary
        self.batch_size = batch_size
        self._out_keys: List[int] = []
        self._out_vals: List[List[float]] = [[] for _ in self.aggs]
        self._carry = _AggState()
        self._emitted = 0
        self._drained = False
        super().__init__(
            "Group",
            f"by=?v{group_var} " + ",".join(f"{a.func}->?v{a.out}" for a in aggs),
        )

    def var_ids(self) -> Tuple[int, ...]:
        base = (self.g,) if self.g is not None else ()
        return base + tuple(a.out for a in self.aggs)

    def sorted_by(self) -> Optional[int]:
        return self.g

    def children(self) -> List[BatchOperator]:
        return [self.child]

    # -- aggregation ------------------------------------------------------------

    def _consume_all(self) -> None:
        while True:
            b = self.child.next_batch()
            if b is None:
                break
            cb = b.compact()
            if cb.n_rows == 0:
                cb.release()
                continue
            keys = (
                cb.column(self.g)
                if self.g is not None
                else np.zeros(cb.n_rows, dtype=np.int32)
            )
            self._consume_batch(keys, cb)
            cb.release()  # aggregates copied into the carry state
        self._close_carry()
        self._drained = True

    def _consume_batch(self, keys: np.ndarray, cb: ColumnBatch) -> None:
        run_keys, starts, lengths = vecops.run_boundaries(keys)
        n_runs = len(run_keys)
        # merge first run into carry if it continues the open group
        first_complete = 0
        if self._carry.key is not None and n_runs and int(run_keys[0]) == self._carry.key:
            self._merge_into_carry(cb, keys, 0, int(lengths[0]))
            first_complete = 1
            if n_runs > 1:
                # the carried group is now provably complete
                self._close_carry()
        elif self._carry.key is not None and n_runs:
            self._close_carry()
        # all complete runs except possibly the last (it may span boundary)
        for i in range(first_complete, n_runs):
            is_last = i == n_runs - 1
            s, ln = int(starts[i]), int(lengths[i])
            if is_last:
                self._carry = _AggState(key=int(run_keys[i]))
                self._merge_into_carry(cb, keys, s, ln)
            else:
                self._carry = _AggState(key=int(run_keys[i]))
                self._merge_into_carry(cb, keys, s, ln)
                self._close_carry()

    def _merge_into_carry(self, cb: ColumnBatch, keys: np.ndarray, s: int, ln: int) -> None:
        c = self._carry
        if c.sums is None:
            c.sums, c.mins, c.maxs = {}, {}, {}
            c.counts, c.distinct = {}, {}
        c.count += ln
        for ai, a in enumerate(self.aggs):
            if a.var is None:  # COUNT(*)
                continue
            codes = cb.column(a.var)[s : s + ln]
            if a.distinct:
                c.distinct.setdefault(ai, set()).update(np.unique(codes).tolist())
                continue
            vals = self.dictionary.numeric_of(codes)
            ok = ~np.isnan(vals)
            v = vals[ok]
            c.counts[ai] = c.counts.get(ai, 0.0) + float(ok.sum())
            if len(v):
                c.sums[ai] = c.sums.get(ai, 0.0) + float(v.sum())
                c.mins[ai] = min(c.mins.get(ai, np.inf), float(v.min()))
                c.maxs[ai] = max(c.maxs.get(ai, -np.inf), float(v.max()))

    def _close_carry(self) -> None:
        c = self._carry
        if c.key is None and c.count == 0:
            return
        self._out_keys.append(c.key if c.key is not None else 0)
        for ai, a in enumerate(self.aggs):
            if a.func == "count" and a.var is None:
                val = c.count
            elif a.distinct:
                val = float(len((c.distinct or {}).get(ai, set())))
            elif a.func == "count":
                val = (c.counts or {}).get(ai, 0.0)
            elif a.func == "sum":
                val = (c.sums or {}).get(ai, 0.0)
            elif a.func == "min":
                val = (c.mins or {}).get(ai, np.nan)
            elif a.func == "max":
                val = (c.maxs or {}).get(ai, np.nan)
            elif a.func == "avg":
                cnt = (c.counts or {}).get(ai, 0.0)
                val = (c.sums or {}).get(ai, 0.0) / cnt if cnt else np.nan
            else:
                raise ValueError(a.func)
            self._out_vals[ai].append(val)
        self._carry = _AggState()

    # -- emission ----------------------------------------------------------------

    def _next(self) -> Optional[ColumnBatch]:
        if not self._drained:
            self._consume_all()
            if self.g is None and not self._out_keys:
                # global aggregate over empty input still yields one row
                self._carry = _AggState(key=0)
                self._carry.count = 0.0
                self._close_carry()
        n = len(self._out_keys)
        if self._emitted >= n:
            return None
        hi = min(self._emitted + self.batch_size, n)
        sl = slice(self._emitted, hi)
        cols = []
        if self.g is not None:
            cols.append(np.asarray(self._out_keys[sl], dtype=np.int32))
        for ai, a in enumerate(self.aggs):
            vals = self._out_vals[ai][sl]
            codes = [
                self.dictionary.encode(
                    int(v) if a.func == "count" or a.distinct or float(v).is_integer() else float(v)
                )
                for v in vals
            ]
            cols.append(np.asarray(codes, dtype=np.int32))
        self._emitted = hi
        return ColumnBatch.from_columns(self.var_ids(), cols, self.g)

    def _reset(self) -> None:
        self.child.reset()
        self._out_keys = []
        self._out_vals = [[] for _ in self.aggs]
        self._carry = _AggState()
        self._emitted = 0
        self._drained = False


class SortGroupBy(BatchOperator):
    """General GROUP BY (multi-var or unsorted input): materialize, sort by
    group keys, delegate to the streaming operator over a composite key."""

    def __init__(
        self,
        child: BatchOperator,
        group_vars: Sequence[int],
        aggs: Sequence[AggSpec],
        dictionary: Dictionary,
        batch_size: int = MAX_BATCH,
    ):
        self.child = child
        self.group_vars = tuple(group_vars)
        self.aggs = list(aggs)
        self.dictionary = dictionary
        self.batch_size = batch_size
        self._src: Optional[BatchOperator] = None
        super().__init__("Group", f"by={self.group_vars} (sort-based)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.group_vars + tuple(a.out for a in self.aggs)

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _ensure(self) -> BatchOperator:
        if self._src is not None:
            return self._src
        vars_, cols = materialize(self.child)
        n = cols.shape[1]
        key_cols = [cols[vars_.index(v)] for v in self.group_vars]
        order = np.lexsort(tuple(reversed(key_cols))) if key_cols else np.arange(n)
        cols = cols[:, order]
        key_cols = [cols[vars_.index(v)] for v in self.group_vars]
        # composite group id: run boundaries across all key columns
        if n:
            change = np.zeros(n, dtype=bool)
            change[0] = True
            for kc in key_cols:
                change[1:] |= kc[1:] != kc[:-1]
            gid = np.cumsum(change).astype(np.int32) - 1
        else:
            gid = np.zeros(0, dtype=np.int32)

        inner_src = MaterializedSource(
            vars_ + (-1,),
            np.concatenate([cols, gid[None, :]], axis=0),
            -1,
            self.batch_size,
            name="GroupSortBuffer",
        )
        stream = StreamingGroupBy(
            inner_src, -1, self.aggs, self.dictionary, self.batch_size
        )
        # drain stream, then translate composite gid back to the key columns
        svars, scols = materialize(stream)
        gids = scols[0]
        first_row = np.zeros(len(gids), dtype=np.int64)
        if n:
            starts = np.nonzero(change)[0]
            first_row = starts[gids]
        out_cols = [kc[first_row] for kc in key_cols]
        for ai in range(len(self.aggs)):
            out_cols.append(scols[1 + ai])
        block = (
            np.stack(out_cols, axis=0)
            if out_cols
            else np.zeros((0, 0), dtype=np.int32)
        )
        self._src = MaterializedSource(
            self.var_ids(), block.astype(np.int32), None, self.batch_size, name="GroupOut"
        )
        return self._src

    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def _reset(self) -> None:
        self.child.reset()
        self._src = None


class StreamingDistinct(BatchOperator):
    """DISTINCT over input sorted by its (single) visible variable, using
    skip() to scroll past duplicates in storage (paper §3.3)."""

    def __init__(self, child: BatchOperator, var: int, use_skip: bool = True):
        assert child.sorted_by() == var
        self.child = child
        self.var = var
        self.use_skip = use_skip and child.supports_skip()
        self._last: Optional[int] = None
        super().__init__("Distinct", f"(?v{var}) streaming")

    def var_ids(self) -> Tuple[int, ...]:
        return (self.var,)

    def sorted_by(self) -> Optional[int]:
        return self.var

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _next(self) -> Optional[ColumnBatch]:
        while True:
            b = self.child.next_batch()
            if b is None:
                return None
            fb = b.compact()
            cb = fb.project((self.var,))
            fb.release()  # project copied the kept column
            if cb.n_rows == 0:
                continue
            keys = cb.column(self.var)
            run_keys, starts, _ = vecops.run_boundaries(keys)
            if self._last is not None:
                keep = run_keys != self._last
                run_keys, starts = run_keys[keep], starts[keep]
            if len(run_keys) == 0:
                continue
            self._last = int(run_keys[-1])
            if self.use_skip:
                # scroll the child past the last seen value
                self.child.skip(self.var, self._last + 1)
            return ColumnBatch.from_columns((self.var,), [run_keys], self.var)

    def _skip(self, var: int, target: int) -> None:
        self.child.skip(var, target)

    def _reset(self) -> None:
        self.child.reset()
        self._last = None


class SortDistinct(BatchOperator):
    """General DISTINCT: materialize + unique rows (sort-based)."""

    def __init__(self, child: BatchOperator, batch_size: int = MAX_BATCH):
        self.child = child
        self.batch_size = batch_size
        self._src: Optional[MaterializedSource] = None
        super().__init__("Distinct", "(sort-based)")

    def var_ids(self) -> Tuple[int, ...]:
        return self.child.var_ids()

    def children(self) -> List[BatchOperator]:
        return [self.child]

    def _ensure(self) -> MaterializedSource:
        if self._src is None:
            vars_, cols = materialize(self.child)
            uniq = np.unique(cols.T, axis=0).T if cols.shape[1] else cols
            sb = vars_[0] if len(vars_) == 1 and uniq.shape[1] else None
            self._src = MaterializedSource(
                vars_, uniq.astype(np.int32), sb, self.batch_size, name="DistinctBuffer"
            )
        return self._src

    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def _reset(self) -> None:
        self.child.reset()
        self._src = None
