"""Vectorized merge join with skip() — the paper's core operator (§3.2).

The classical sort-merge join decomposed into three phases:

  Probe  — find matching *groups*: pairs of (left range, right range) with
           the same join-key value, detected as runs in the sorted key
           columns of the current windows.
  Build  — materialize groups one column at a time: each left value is
           expanded by the right range length, each right range repeated by
           the left range length. Computed slot-parallel as gather indices
           (vecops.expand_cross / kernels join_expand), so the build is a
           pure vector map — the paper's 'column-based cross product, never
           looking at more than one column at a time'.
  Skip   — gallop the side whose last key is smaller via child.skip(),
           exploiting sorted storage (the BARQ contribution over
           CockroachDB's vectorized merge joiner).

Right-side ranges can span batches; the right window accumulates them in an
amortized ring/doubling buffer (append is in-place, trims are head-offset
bumps — no whole-window copies; DESIGN.md §2.3) and spills to disk beyond a
threshold (paper: 'a special collection that can spill off to disk'); a
spilled window trims and gathers without being read back. Emission runs
through the fused gather_emit kernel: gather + NULL-extension + the
vectorized multi-key equality pass (§3.2 Multiple Join Keys) in one
dispatch, writing straight into a pool-recycled output buffer. Modes:
inner, left_outer (OPTIONAL, incl. the per-group all-rows-filtered →
NULL-row case the paper sketches), semi (EXISTS) and anti (MINUS) on the
same machinery.
"""

from __future__ import annotations

import os
import tempfile
from typing import List, Optional, Tuple

import numpy as np

from repro.core import vecops
from repro.core.adaptive import AdaptiveBatchSizer
from repro.core.batch import NULL_ID, BatchPool, ColumnBatch, bucket_for
from repro.core.expressions import eval_expr_mask
from repro.core.exprs import eval_program_mask
from repro.core.operators.base import BatchOperator
from repro.kernels import ops as KOPS

_SPILL_THRESHOLD_ROWS = 1 << 20
_WINDOW_MIN_CAP = 256  # rows; first append sizes the buffer (pow2 doubling)


class _Window:
    """Sorted row window for one side: payload columns keyed by the join
    variable, accumulated across child batches and trimmed as the other
    side advances past keys.

    Implemented as an amortized ring/doubling buffer: live rows occupy
    ``_buf[:, head:tail]``. ``append_batch`` writes in place at the tail
    (compacting to the front or doubling capacity only when out of room, so
    total copy traffic is O(rows appended), not O(rows × batches));
    ``drop_prefix``/``trim_below`` just advance the head. A spilled window
    (memory-mapped) keeps trimming and gathering without being read back —
    only a subsequent append materializes it."""

    def __init__(
        self,
        var_ids: Tuple[int, ...],
        key_var: int,
        spill_dir: Optional[str],
        pool: Optional[BatchPool] = None,
    ):
        self.var_ids = var_ids
        self.key_pos = var_ids.index(key_var)
        self._buf: np.ndarray = np.empty((len(var_ids), 0), dtype=np.int32)
        self._head = 0
        self._tail = 0
        self.exhausted = False
        self.spill_dir = spill_dir
        self.pool = pool  # copy-traffic accounting + recycling of consumed batches
        self._spill_path: Optional[str] = None
        self._spilled = False

    # -- views (no copies) -------------------------------------------------

    @property
    def cols(self) -> np.ndarray:
        """Live rows as an (n_vars, n) view."""
        return self._buf[:, self._head : self._tail]

    @property
    def keys(self) -> np.ndarray:
        return self._buf[self.key_pos, self._head : self._tail]

    @property
    def n(self) -> int:
        return self._tail - self._head

    def last_key(self) -> int:
        return int(self._buf[self.key_pos, self._tail - 1])

    # -- mutation ----------------------------------------------------------

    def append_batch(self, b: ColumnBatch) -> int:
        n = b.n_active
        if n == 0:
            b.release()
            return 0
        self._reserve(n)
        dst = self._buf[:, self._tail : self._tail + n]
        contiguous = n == b.n_rows
        sel = None if contiguous else b.selection_vector()
        for j, v in enumerate(self.var_ids):
            src = b.columns[b.col_index(v)]
            dst[j] = src[:n] if contiguous else src[sel]
        self._tail += n
        if self.pool is not None:
            self.pool.bytes_copied += dst.nbytes
        b.release()
        if (
            self.spill_dir
            and not self._spilled
            and self.n > _SPILL_THRESHOLD_ROWS
        ):
            self._spill()
        return n

    def drop_prefix(self, k: int) -> None:
        if k > 0:
            self._head += k  # O(1); valid for spilled windows too

    def trim_below(self, key: int) -> int:
        """Drop rows with keys < key; returns number dropped."""
        if self.n == 0:
            return 0
        cut = int(np.searchsorted(self.keys, key, side="left"))
        self.drop_prefix(cut)
        return cut

    def gather(self, idx: np.ndarray) -> np.ndarray:
        return np.asarray(self._buf[:, self._head + idx])

    def close(self) -> None:
        if self._spill_path is not None:
            self._buf = np.empty((len(self.var_ids), 0), dtype=np.int32)
            self._head = self._tail = 0
            self._spilled = False
            os.unlink(self._spill_path)
            self._spill_path = None

    # -- internals ---------------------------------------------------------

    def _reserve(self, n: int) -> None:
        if self._spilled:
            self._materialize(extra=n)
        cap = int(self._buf.shape[1])
        if self._tail + n <= cap:
            return
        live = self.n
        if live + n <= cap and self._head >= live:
            # shift live rows to the front (regions don't overlap); the head
            # must clear half the buffer first, so each row is moved O(1)
            # times on average
            self._buf[:, :live] = self._buf[:, self._head : self._tail]
            if self.pool is not None:
                self.pool.bytes_copied += live * len(self.var_ids) * 4
            self._head, self._tail = 0, live
            return
        new_cap = max(cap, _WINDOW_MIN_CAP)
        while new_cap < live + n:
            new_cap *= 2
        nb = np.empty((len(self.var_ids), new_cap), dtype=np.int32)
        nb[:, :live] = self._buf[:, self._head : self._tail]
        if self.pool is not None:
            self.pool.bytes_copied += live * len(self.var_ids) * 4
        self._buf, self._head, self._tail = nb, 0, live

    def _spill(self) -> None:
        fd, path = tempfile.mkstemp(suffix=".npy", dir=self.spill_dir)
        os.close(fd)
        np.save(path, self._buf[:, self._head : self._tail])
        live = self.n
        self._spill_path = path
        self._buf = np.load(path, mmap_mode="r")
        self._head, self._tail = 0, live
        self._spilled = True

    def _materialize(self, extra: int = 0) -> None:
        live = self.n
        cap = _WINDOW_MIN_CAP
        while cap < live + extra:
            cap *= 2
        nb = np.empty((len(self.var_ids), cap), dtype=np.int32)
        nb[:, :live] = np.asarray(self._buf[:, self._head : self._tail])
        if self.pool is not None:
            self.pool.bytes_copied += live * len(self.var_ids) * 4
        self._buf, self._head, self._tail = nb, 0, live
        self._spilled = False
        if self._spill_path is not None:
            os.unlink(self._spill_path)
            self._spill_path = None


class MergeJoin(BatchOperator):
    def __init__(
        self,
        left: BatchOperator,
        right: BatchOperator,
        join_var: int,
        mode: str = "inner",
        post_filter=None,  # Expr over materialized rows (OPTIONAL {...} FILTER)
        dictionary=None,
        sizer: Optional[AdaptiveBatchSizer] = None,
        spill_dir: Optional[str] = None,
        allow_child_skip: bool = True,
        pool: Optional[BatchPool] = None,
        post_program=None,  # compiled ExprProgram for post_filter (planner)
    ) -> None:
        assert mode in ("inner", "left_outer", "semi", "anti")
        assert left.sorted_by() == join_var, "left child must be sorted by join var"
        assert right.sorted_by() == join_var, "right child must be sorted by join var"
        self.left = left
        self.right = right
        self.v = join_var
        self.mode = mode
        self.post_filter = post_filter
        self.dictionary = dictionary
        if post_program is False:  # planner: known uncompilable, no retry
            post_program = None
        elif post_program is None and post_filter is not None and dictionary is not None:
            from repro.core.operators.simple import _resolve_program

            post_program = _resolve_program(post_filter, dictionary, None, "mask")
        self.post_program = post_program
        self.sizer = sizer or AdaptiveBatchSizer(initial=256)
        self.allow_child_skip = allow_child_skip
        self.pool = pool

        lv, rv = tuple(left.var_ids()), tuple(right.var_ids())
        self.shared = tuple(x for x in lv if x in rv)
        assert join_var in self.shared
        self.secondary = tuple(x for x in self.shared if x != join_var)
        if mode in ("semi", "anti"):
            self._right_out: Tuple[int, ...] = ()
        else:
            self._right_out = tuple(x for x in rv if x not in lv)
        self._out_vars: Tuple[int, ...] = lv + self._right_out

        # static gather_emit plan: emit all left rows, then the right-only
        # rows; secondary keys become fused equality pairs
        self._lsel = tuple(range(len(lv)))
        self._rsel = tuple(rv.index(x) for x in self._right_out)
        self._pairs = tuple((lv.index(sv), rv.index(sv)) for sv in self.secondary)

        self._lwin = _Window(lv, join_var, None, pool)
        self._rwin = _Window(rv, join_var, spill_dir, pool)
        self._lmatched = np.zeros(0, dtype=bool)  # aligned with left window
        # pending build: (lstarts, llens, rstarts, rlens, cum, emitted)
        self._pending: Optional[Tuple] = None
        self._finalize_l_hi: Optional[int] = None
        self._leftover_queue: List[np.ndarray] = []  # (n_lvars, n) row blocks
        self._done = False
        # does matched-tracking require materialization?
        self._needs_expansion_for_match = bool(self.secondary) or post_filter is not None
        super().__init__("MergeJoin", f"(?v{join_var}) mode={mode}")

    # -- metadata ---------------------------------------------------------------

    def var_ids(self) -> Tuple[int, ...]:
        return self._out_vars

    def sorted_by(self) -> Optional[int]:
        # left_outer interleaves NULL-extended rows after each probe window,
        # breaking global key order; inner/semi/anti preserve it.
        return None if self.mode == "left_outer" else self.v

    def children(self) -> List[BatchOperator]:
        return [self.left, self.right]

    # -- iteration ----------------------------------------------------------------

    def _next(self) -> Optional[ColumnBatch]:
        cap = bucket_for(self.sizer.on_next())
        while True:
            if self._pending is not None:
                out = self._emit_pending(cap)
                if self._pending is None and self._finalize_l_hi is not None:
                    self._finalize_probe()
                if out is not None and out.n_active > 0:
                    return out
                continue
            if self._finalize_l_hi is not None:
                self._finalize_probe()
                continue
            if self._leftover_queue:
                return self._emit_leftovers(cap)
            if self._done:
                return None
            if not self._advance():
                self._done = True

    def _skip(self, var: int, target: int) -> None:
        if var != self.v:
            raise ValueError("skip on non-join var")
        self._pending = None
        self._finalize_l_hi = None
        self._leftover_queue.clear()
        dropped = self._lwin.trim_below(target)
        self._lmatched = self._lmatched[dropped:]
        self._rwin.trim_below(target)
        if self.left.supports_skip():
            self.left.skip(self.v, target)
        if self.right.supports_skip():
            self.right.skip(self.v, target)

    def _close(self) -> None:
        # _Window.close is idempotent, so teardown after _reset (or a
        # second close from an outer finally) is safe
        self._lwin.close()
        self._rwin.close()

    def _reset(self) -> None:
        self.left.reset()
        self.right.reset()
        self._lwin.close()
        self._rwin.close()
        self._lwin = _Window(self._lwin.var_ids, self.v, None, self.pool)
        self._rwin = _Window(self._rwin.var_ids, self.v, self._rwin.spill_dir, self.pool)
        self._lmatched = np.zeros(0, dtype=bool)
        self._pending = None
        self._finalize_l_hi = None
        self._leftover_queue.clear()
        self._done = False

    # -- fetch helpers -------------------------------------------------------------

    def _fetch_left(self) -> bool:
        if self._lwin.exhausted:
            return False
        b = self.left.next_batch()
        if b is None:
            self._lwin.exhausted = True
            return False
        grown = self._lwin.append_batch(b)
        if grown:
            self._lmatched = np.concatenate(
                [self._lmatched, np.zeros(grown, dtype=bool)]
            )
        return True

    def _fetch_right(self) -> bool:
        if self._rwin.exhausted:
            return False
        b = self.right.next_batch()
        if b is None:
            self._rwin.exhausted = True
            return False
        self._rwin.append_batch(b)
        return True

    # -- state machine ----------------------------------------------------------------

    def _advance(self) -> bool:
        """Create new work (a pending build or queued leftovers).
        Returns False when fully exhausted."""
        while self._lwin.n == 0:
            if not self._fetch_left():
                return False
        while self._rwin.n == 0 and not self._rwin.exhausted:
            self._fetch_right()

        if self._rwin.n == 0:  # right side is empty and exhausted
            if self.mode in ("left_outer", "anti"):
                # every remaining left row is unmatched
                self._probe(self._lwin.n)
                return True
            return False

        # Probe boundary: right runs with key < the window's last key are
        # complete; the last run may continue into the next right batch
        # (unless the right side is exhausted).
        if self._rwin.exhausted:
            l_hi = self._lwin.n
        else:
            r_boundary = self._rwin.last_key()
            l_hi = int(np.searchsorted(self._lwin.keys, r_boundary, side="left"))

        if l_hi > 0:
            self._probe(l_hi)
            return True

        # Left frontier is at/above the right boundary: grow the right window.
        l_first = int(self._lwin.keys[0])
        if self.allow_child_skip and self.right.supports_skip() and self._rwin.last_key() < l_first:
            # Skip phase: gallop right to the left frontier (paper 3.a)
            self.right.skip(self.v, l_first)
        self._fetch_right()
        return True

    def _probe(self, l_hi: int) -> None:
        """Probe left rows [0, l_hi) against the right window; queue the
        build. Finalization (matched bookkeeping + trims) happens after the
        build is fully emitted."""
        lkeys = self._lwin.keys[:l_hi]
        lvals, lstarts, llens = vecops.run_boundaries(lkeys)
        rvals, rstarts, rlens = vecops.run_boundaries(self._rwin.keys)
        gl, gr = vecops.probe_groups(lvals, rvals)

        if len(gl) and not self._needs_expansion_for_match:
            # fast path: primary-key membership decides matched. The ranges
            # are marked with a +1/-1 boundary diff + running sum instead of
            # a per-group Python loop.
            d = np.zeros(l_hi + 1, dtype=np.int32)
            ls, ll = lstarts[gl], llens[gl]
            np.add.at(d, ls, 1)
            np.add.at(d, ls + ll, -1)
            np.logical_or(
                self._lmatched[:l_hi], np.cumsum(d[:-1]) > 0,
                out=self._lmatched[:l_hi],
            )

        need_build = len(gl) > 0 and (
            self.mode in ("inner", "left_outer") or self._needs_expansion_for_match
        )
        if need_build:
            g_ls, g_ll = lstarts[gl], llens[gl]
            g_rs, g_rl = rstarts[gr], rlens[gr]
            cum = vecops.group_output_offsets(g_ll, g_rl)
            if int(cum[-1]) > 0:
                self._pending = (g_ls, g_ll, g_rs, g_rl, cum, 0)
        self._finalize_l_hi = l_hi

    def _finalize_probe(self) -> None:
        l_hi = self._finalize_l_hi
        self._finalize_l_hi = None
        if self.mode == "semi":
            sel = np.nonzero(self._lmatched[:l_hi])[0].astype(np.int32)
            if len(sel):
                self._leftover_queue.append(self._lwin.gather(sel))
        elif self.mode in ("left_outer", "anti"):
            um = np.nonzero(~self._lmatched[:l_hi])[0].astype(np.int32)
            if len(um):
                self._leftover_queue.append(self._lwin.gather(um))

        self._lwin.drop_prefix(l_hi)
        self._lmatched = self._lmatched[l_hi:]

        if self._lwin.n > 0:
            self._rwin.trim_below(int(self._lwin.keys[0]))
        elif not self._lwin.exhausted:
            # Skip phase: gallop left to the right frontier (inner/semi only —
            # outer/anti must still observe unmatched left rows)
            if (
                self._rwin.n > 0
                and self.allow_child_skip
                and self.mode in ("inner", "semi")
                and self.left.supports_skip()
            ):
                self.left.skip(self.v, int(self._rwin.keys[0]))
            self._fetch_left()
            if self._lwin.n > 0:
                self._rwin.trim_below(int(self._lwin.keys[0]))

    # -- emission ----------------------------------------------------------------

    def _emit_pending(self, cap: int) -> Optional[ColumnBatch]:
        g_ls, g_ll, g_rs, g_rl, cum, emitted = self._pending
        total = int(cum[-1])
        count = min(cap, total - emitted)
        li, ri = KOPS.join_expand(g_ls, g_ll, g_rs, g_rl, cum, emitted, count)
        emitted += count
        self._pending = None if emitted >= total else (g_ls, g_ll, g_rs, g_rl, cum, emitted)

        if self.mode in ("semi", "anti") and self.post_filter is None:
            # expansion only feeds matched-tracking: fused mask, no columns
            _, mask = KOPS.gather_emit(
                self._lwin.cols, self._rwin.cols, li, ri, (), (), self._pairs
            )
            if mask.any():
                self._lmatched[li[mask]] = True
            return None

        b = ColumnBatch.alloc(
            self._out_vars, bucket_for(max(count, 1)), self.pool, self.v
        )
        _, mask = KOPS.gather_emit(
            self._lwin.cols, self._rwin.cols, li, ri,
            self._lsel, self._rsel, self._pairs, out=b.columns,
        )
        b.n_rows = count
        if count < b.capacity:
            b.columns[:, count:] = NULL_ID
        b.mask[:count] = mask
        if self.pool is not None:
            self.pool.bytes_copied += len(self._out_vars) * count * 4
        if self.post_filter is not None:
            # OPTIONAL {...} FILTER condition: fused VM program when the
            # planner compiled one, interpreted walk otherwise
            if self.post_program is not None:
                b = b.with_mask(
                    eval_program_mask(self.post_program, b, self.dictionary)
                )
            else:
                b = b.with_mask(eval_expr_mask(self.post_filter, b, self.dictionary))

        if self._needs_expansion_for_match:
            surv = b.mask[:count]
            if surv.any():
                self._lmatched[li[surv]] = True

        if self.mode in ("semi", "anti"):
            b.release()
            return None  # expansion only feeds matched-tracking
        if b.n_active:
            return b
        b.release()
        return None

    def _emit_leftovers(self, cap: int) -> ColumnBatch:
        rows = self._leftover_queue.pop(0)
        n = rows.shape[1]
        if n > cap:
            self._leftover_queue.insert(0, rows[:, cap:])
            rows = rows[:, :cap]
            n = cap
        out_cols = [rows[i] for i in range(rows.shape[0])]
        for _ in self._right_out:
            out_cols.append(np.full(n, NULL_ID, dtype=np.int32))
        return ColumnBatch.from_columns(self._out_vars, out_cols, self.v, pool=self.pool)
