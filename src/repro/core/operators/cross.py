"""Cartesian product for disconnected plan fragments (rare; the planner
only emits it when no join variable exists). Reuses the Build-phase
expansion machinery with a single group spanning both sides."""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import vecops
from repro.core.batch import BatchPool, ColumnBatch, bucket_for
from repro.core.operators.base import BatchOperator
from repro.core.operators.sort import materialize


class CrossJoin(BatchOperator):
    def __init__(
        self,
        left: BatchOperator,
        right: BatchOperator,
        pool: Optional[BatchPool] = None,
    ):
        self.left = left
        self.right = right
        self.pool = pool
        lv = tuple(left.var_ids())
        self._right_out = tuple(v for v in right.var_ids() if v not in lv)
        self._vars = lv + self._right_out
        self._lcols: Optional[np.ndarray] = None
        self._rcols: Optional[np.ndarray] = None
        self._emitted = 0
        super().__init__("Cross", "")

    def var_ids(self) -> Tuple[int, ...]:
        return self._vars

    def children(self) -> List[BatchOperator]:
        return [self.left, self.right]

    def _ensure(self) -> None:
        if self._lcols is None:
            self._lvars, self._lcols = materialize(self.left)
            self._rvars, self._rcols = materialize(self.right)

    def _next(self) -> Optional[ColumnBatch]:
        self._ensure()
        nl, nr = self._lcols.shape[1], self._rcols.shape[1]
        total = nl * nr
        if self._emitted >= total:
            return None
        cap = bucket_for(4096)
        count = min(cap, total - self._emitted)
        cum = np.asarray([0, total], dtype=np.int64)
        li, ri = vecops.expand_cross(
            np.zeros(1, dtype=np.int32),
            np.asarray([nl], dtype=np.int32),
            np.zeros(1, dtype=np.int32),
            np.asarray([nr], dtype=np.int32),
            cum,
            self._emitted,
            count,
        )
        self._emitted += count
        cols = [self._lcols[self._lvars.index(v), li] for v in self._lvars]
        for v in self._right_out:
            cols.append(self._rcols[self._rvars.index(v), ri])
        return ColumnBatch.from_columns(self._vars, cols, None, pool=self.pool)

    def _reset(self) -> None:
        self.left.reset()
        self.right.reset()
        self._lcols = None
        self._emitted = 0
