"""Index scan operator: evaluates one triple pattern over a sorted index.

Produces columnar batches sorted by the first free role of the chosen index
order. Supports ``skip()`` on that role (the storage seek), drives the
adaptive batch sizer from the received next()/skip() pattern (paper §3.4),
and counts rows read from storage so benchmarks can report overfetching.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.adaptive import AdaptiveBatchSizer
from repro.core.algebra import K, TriplePattern, V
from repro.core.batch import BatchPool, ColumnBatch
from repro.core.operators.base import BatchOperator
from repro.core.storage import INDEX_ORDERS, QuadStore, ScanRange

_ROLE_NAMES = ("s", "p", "o", "g")


class IndexScan(BatchOperator):
    def __init__(
        self,
        store: QuadStore,
        pattern: TriplePattern,
        want_sorted_var: Optional[int] = None,
        sizer: Optional[AdaptiveBatchSizer] = None,
        detail: str = "",
        pool: Optional[BatchPool] = None,
    ) -> None:
        self.store = store
        self.pattern = pattern
        self.pool = pool

        # encode constant slots; a constant not present in the dictionary
        # means the pattern matches nothing
        self._dead = False
        bound: List[Optional[int]] = [None, None, None, None]
        slots = (pattern.s, pattern.p, pattern.o, pattern.g)
        for role, sl in enumerate(slots):
            if isinstance(sl, K):
                tid = store.dict.lookup(sl.term)
                if tid is None:
                    self._dead = True
                    tid = -1
                bound[role] = tid
        self.bound = bound

        # free roles and their variables; repeated vars inside one pattern
        # (e.g. ?x :p ?x) add a residual equality mask
        self.role_of_var: Dict[int, int] = {}
        self.residual_pairs: List[Tuple[int, int]] = []  # (role_a, role_b)
        for role, sl in enumerate(slots):
            if isinstance(sl, V):
                if sl.id in self.role_of_var:
                    self.residual_pairs.append((self.role_of_var[sl.id], role))
                else:
                    self.role_of_var[sl.id] = role

        want_role = self.role_of_var.get(want_sorted_var) if want_sorted_var is not None else None
        self.index = store.choose_index(bound, want_role)
        self.perm = INDEX_ORDERS[self.index]

        # column position (within the index order) of each output variable
        self._var_ids = tuple(self.role_of_var)
        self.var_col_pos = {
            v: self.perm.index(self.role_of_var[v]) for v in self._var_ids
        }
        # sortedness: the first free position in the index order
        n_bound = 0
        while n_bound < 4 and bound[self.perm[n_bound]] is not None:
            n_bound += 1
        self._sort_col_pos = n_bound if n_bound < 4 else None
        self._sorted_var: Optional[int] = None
        if self._sort_col_pos is not None:
            role = self.perm[self._sort_col_pos]
            for v, r in self.role_of_var.items():
                if r == role:
                    self._sorted_var = v

        self.range: ScanRange = (
            ScanRange(self.index, 0, 0)
            if self._dead
            else store.range_for_pattern(self.index, bound)
        )
        self.offset = 0
        self.sizer = sizer or AdaptiveBatchSizer()
        super().__init__("Scan", detail or self._describe())

    def _describe(self) -> str:
        parts = []
        slots = (self.pattern.s, self.pattern.p, self.pattern.o)
        for sl in slots:
            parts.append(f"?v{sl.id}" if isinstance(sl, V) else str(sl.term))
        return f"({', '.join(parts)}) [{self.index}]"

    # -- operator API -----------------------------------------------------------

    def var_ids(self) -> Tuple[int, ...]:
        return self._var_ids

    def sorted_by(self) -> Optional[int]:
        return self._sorted_var

    def _next(self) -> Optional[ColumnBatch]:
        if self.offset >= len(self.range):
            return None
        count = self.sizer.on_next()
        rows = self.store.read(self.range, self.offset, count)
        self.offset += len(rows)
        self.stats.rows_scanned += len(rows)
        cols = [rows[:, self.var_col_pos[v]] for v in self._var_ids]
        b = ColumnBatch.from_columns(
            self._var_ids, cols, self._sorted_var, pool=self.pool
        )
        for ra, rb in self.residual_pairs:
            pa, pb = self.perm.index(ra), self.perm.index(rb)
            m = np.zeros(b.capacity, dtype=bool)
            m[: b.n_rows] = rows[:, pa] == rows[:, pb]
            b = b.with_mask(m)
        return b

    def _skip(self, var: int, target: int) -> None:
        if var != self._sorted_var or self._sort_col_pos is None:
            raise ValueError("skip on unsorted variable")
        self.sizer.on_skip()
        self.offset = self.store.seek(
            self.range, self.offset, self._sort_col_pos, target
        )

    def _reset(self) -> None:
        self.offset = 0
        self.sizer.on_reset()

    # cardinality for the planner
    def estimated_rows(self) -> int:
        return len(self.range)
