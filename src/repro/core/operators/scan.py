"""Index scan operator: evaluates one triple pattern over a sorted index.

Produces columnar batches sorted by the first free role of the chosen index
order. Supports ``skip()`` on that role (the storage seek), drives the
adaptive batch sizer from the received next()/skip() pattern (paper §3.4),
and counts rows read from storage so benchmarks can report overfetching.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.adaptive import AdaptiveBatchSizer
from repro.core.algebra import K, TriplePattern, V
from repro.core.batch import BatchPool, ColumnBatch
from repro.core.operators.base import BatchOperator
from repro.core.sip import SipFilter
from repro.core.storage import INDEX_ORDERS, QuadStore, ScanRange

_ROLE_NAMES = ("s", "p", "o", "g")


class IndexScan(BatchOperator):
    def __init__(
        self,
        store: QuadStore,
        pattern: TriplePattern,
        want_sorted_var: Optional[int] = None,
        sizer: Optional[AdaptiveBatchSizer] = None,
        detail: str = "",
        pool: Optional[BatchPool] = None,
        sip_filters: Sequence[SipFilter] = (),
    ) -> None:
        self.store = store
        self.pattern = pattern
        self.pool = pool
        # sideways-information-passing prefilters (DESIGN.md §12): each is
        # a bloom/range summary of some downstream join's build side. On
        # the sorted var they narrow the scan through skip()/seek; on other
        # vars they mask batches. Applied lazily on the first next() so the
        # exporting join's build phase has run by then.
        self.sip_filters = list(sip_filters)
        self._sip_pending = bool(self.sip_filters)
        self._sip_hi: Optional[int] = None

        # encode constant slots; a constant not present in the dictionary
        # means the pattern matches nothing
        self._dead = False
        bound: List[Optional[int]] = [None, None, None, None]
        slots = (pattern.s, pattern.p, pattern.o, pattern.g)
        for role, sl in enumerate(slots):
            if isinstance(sl, K):
                tid = store.dict.lookup(sl.term)
                if tid is None:
                    self._dead = True
                    tid = -1
                bound[role] = tid
        self.bound = bound

        # free roles and their variables; repeated vars inside one pattern
        # (e.g. ?x :p ?x) add a residual equality mask
        self.role_of_var: Dict[int, int] = {}
        self.residual_pairs: List[Tuple[int, int]] = []  # (role_a, role_b)
        for role, sl in enumerate(slots):
            if isinstance(sl, V):
                if sl.id in self.role_of_var:
                    self.residual_pairs.append((self.role_of_var[sl.id], role))
                else:
                    self.role_of_var[sl.id] = role

        want_role = self.role_of_var.get(want_sorted_var) if want_sorted_var is not None else None
        self.index = store.choose_index(bound, want_role)
        self.perm = INDEX_ORDERS[self.index]

        # column position (within the index order) of each output variable
        self._var_ids = tuple(self.role_of_var)
        self.var_col_pos = {
            v: self.perm.index(self.role_of_var[v]) for v in self._var_ids
        }
        # sortedness: the first free position in the index order
        n_bound = 0
        while n_bound < 4 and bound[self.perm[n_bound]] is not None:
            n_bound += 1
        self._sort_col_pos = n_bound if n_bound < 4 else None
        self._sorted_var: Optional[int] = None
        if self._sort_col_pos is not None:
            role = self.perm[self._sort_col_pos]
            for v, r in self.role_of_var.items():
                if r == role:
                    self._sorted_var = v

        self.range: ScanRange = (
            ScanRange(self.index, 0, 0)
            if self._dead
            else store.range_for_pattern(self.index, bound)
        )
        self.offset = 0
        self.sizer = sizer or AdaptiveBatchSizer()
        super().__init__("Scan", detail or self._describe())

    def _describe(self) -> str:
        parts = []
        slots = (self.pattern.s, self.pattern.p, self.pattern.o)
        for sl in slots:
            parts.append(f"?v{sl.id}" if isinstance(sl, V) else str(sl.term))
        return f"({', '.join(parts)}) [{self.index}]"

    # -- operator API -----------------------------------------------------------

    def var_ids(self) -> Tuple[int, ...]:
        return self._var_ids

    def sorted_by(self) -> Optional[int]:
        return self._sorted_var

    def _next(self) -> Optional[ColumnBatch]:
        if self._sip_pending:
            self._apply_sip_ranges()
        while True:
            if self.offset >= len(self.range):
                return None
            count = self.sizer.on_next()
            rows = self.store.read(self.range, self.offset, count)
            self.offset += len(rows)
            self.stats.rows_scanned += len(rows)
            if self._sip_hi is not None and len(rows):
                keys = rows[:, self._sort_col_pos]
                if keys[0] > self._sip_hi:
                    # galloped past the build-side range: the scan is done
                    self.offset = len(self.range)
                    return None
                if keys[-1] > self._sip_hi:
                    end = int(np.searchsorted(keys, self._sip_hi, "right"))
                    rows = rows[:end]
                    self.offset = len(self.range)
            cols = [rows[:, self.var_col_pos[v]] for v in self._var_ids]
            b = ColumnBatch.from_columns(
                self._var_ids, cols, self._sorted_var, pool=self.pool
            )
            for ra, rb in self.residual_pairs:
                pa, pb = self.perm.index(ra), self.perm.index(rb)
                m = np.zeros(b.capacity, dtype=bool)
                m[: b.n_rows] = rows[:, pa] == rows[:, pb]
                b = b.with_mask(m)
            b = self._apply_sip_masks(b)
            if b.n_active or self.offset >= len(self.range):
                return b
            # fully pruned by SIP: read the next chunk instead of bouncing
            # an empty batch up the pipeline
            b.release()

    # -- sideways information passing (DESIGN.md §12) ---------------------------

    def _apply_sip_ranges(self) -> None:
        """Code-range narrowing on the sorted var, once, before the first
        read: seek to the build side's min key and stop past its max —
        the skip() machinery applied sideways instead of from a parent."""
        self._sip_pending = False
        for f in self.sip_filters:
            if not self.can_skip(f.var):
                continue  # unsorted var: mask-mode only (no exceptions)
            rng = f.code_range()
            if rng is None:
                continue
            lo, hi = rng
            if hi < lo:  # provably empty build side: nothing can match
                self.offset = len(self.range)
                return
            self.offset = self.store.seek(
                self.range, self.offset, self._sort_col_pos, lo
            )
            self._sip_hi = hi if self._sip_hi is None else min(self._sip_hi, hi)
            self.stats.extra["sip_range_seeks"] = (
                self.stats.extra.get("sip_range_seeks", 0) + 1
            )

    def _apply_sip_masks(self, b: ColumnBatch) -> ColumnBatch:
        for f in self.sip_filters:
            m = f.mask(b.columns[b.col_index(f.var), : b.n_rows])
            if m is None:
                continue
            full = np.ones(b.capacity, dtype=bool)
            full[: b.n_rows] = m
            b = b.with_mask(full)
        if self.sip_filters:
            self.stats.extra["sip_pruned_rows"] = sum(
                f.rows_pruned for f in self.sip_filters
            )
            self.stats.extra["sip_probe_dispatches"] = sum(
                f.probe_dispatches for f in self.sip_filters
            )
        return b

    def can_skip(self, var: Optional[int]) -> bool:
        return (
            var is not None
            and var == self._sorted_var
            and self._sort_col_pos is not None
        )

    def _skip(self, var: int, target: int) -> None:
        if not self.can_skip(var):
            raise ValueError("skip on unsorted variable")
        self.sizer.on_skip()
        self.offset = self.store.seek(
            self.range, self.offset, self._sort_col_pos, target
        )

    def _reset(self) -> None:
        self.offset = 0
        self.sizer.on_reset()
        self._sip_pending = bool(self.sip_filters)
        self._sip_hi = None

    # cardinality for the planner
    def estimated_rows(self) -> int:
        return len(self.range)

    def sip_code_range(self) -> Tuple[int, int]:
        """Inclusive (lo, hi) of the sort column over the whole range —
        O(1) off the sorted index, the range-only SipFilter payload a
        merely-sorted merge-join build side can export without
        materializing. (0, -1) when the scan is empty."""
        n = len(self.range)
        if n == 0 or self._sort_col_pos is None:
            return 0, -1
        first = self.store.read(self.range, 0, 1)[0, self._sort_col_pos]
        last = self.store.read(self.range, n - 1, 1)[0, self._sort_col_pos]
        return int(first), int(last)
