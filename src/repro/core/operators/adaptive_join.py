"""Mid-plan adaptive join re-strategy (DESIGN.md §15).

The planner picks merge vs hash from *estimated* cardinalities. When the
estimate on a merge join's build (right/sort) input is badly wrong —
q-error at or past the profiler's MISEST threshold — the sort that makes
merge viable can cost more than a hash build over the same rows.

``AdaptiveMergeJoin`` defers that decision to the first ``next_batch()``
call: the right input is a pipeline breaker either way (it feeds a Sort
in the static plan), so we materialize it first, compare the actual row
count against the planner's estimate, and only then instantiate the real
join operator:

  * estimate held up (or hash would not be cheaper) -> sort the block and
    run the planned ``MergeJoin``;
  * build blew past the estimate (q >= QERROR_FLAG) and a hash build is
    cheaper than the sort -> run ``HashJoin`` with the already-
    materialized block as the build side.  The probe (left) stream is
    consumed as-is; its sort order is simply ignored.

The planner only marks a merge join ``adaptive_ok`` when no ancestor
depends on its output order (``Planner._mark_adaptive``), so the switch
can never silently break a streaming group-by or merge-join parent.
The decision is recorded in ``OpStats.extra`` (``adaptive_switches``,
``adaptive_qerror``) and therefore shows up in EXPLAIN ANALYZE, the
profiler tree, and serving metrics.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.batch import BatchPool, ColumnBatch
from repro.core.operators.base import BatchOperator
from repro.core.operators.hash_join import HashJoin
from repro.core.operators.merge_join import MergeJoin
from repro.core.operators.sort import MaterializedSource, materialize
from repro.core.profiler import QERROR_FLAG, q_error

# Mirrors the planner's cost model (planner._HASH_BUILD_FACTOR): hashing a
# build row costs ~4x streaming it, a sort costs n*log2(n).
_HASH_BUILD_FACTOR = 4.0


class AdaptiveMergeJoin(BatchOperator):
    """Planned merge join that may re-strategize to hash at runtime."""

    def __init__(
        self,
        left: BatchOperator,
        right: BatchOperator,  # UNSORTED build input (the planned Sort's child)
        join_var: int,
        mode: str = "inner",
        post_filter=None,
        dictionary=None,
        post_program=None,
        pool: Optional[BatchPool] = None,
        spill_dir: Optional[str] = None,
        est_build: float = 0.0,  # planner's est_rows for the right input
        memory_budget: Optional[int] = None,
    ) -> None:
        assert mode in ("inner", "left_outer", "semi", "anti")
        self.left = left
        self.right = right
        self.v = join_var
        self.mode = mode
        self.post_filter = post_filter
        self.dictionary = dictionary
        self.post_program = post_program
        self.pool = pool
        self.spill_dir = spill_dir
        self.est_build = float(est_build)
        self.memory_budget = memory_budget
        self._inner: Optional[BatchOperator] = None

        lv, rv = tuple(left.var_ids()), tuple(right.var_ids())
        assert join_var in lv and join_var in rv
        self._shared = tuple(x for x in lv if x in rv)
        if mode in ("semi", "anti"):
            self._out_vars: Tuple[int, ...] = lv
        else:
            self._out_vars = lv + tuple(x for x in rv if x not in lv)
        super().__init__("AdaptiveJoin", f"(?v{join_var}) mode={mode}")

    # -- metadata ---------------------------------------------------------------
    def var_ids(self) -> Tuple[int, ...]:
        return self._out_vars

    def sorted_by(self) -> Optional[int]:
        # Even when the merge branch wins, advertise no order: the planner
        # only lowers to AdaptiveMergeJoin when no ancestor needs it, and a
        # stable contract keeps parents from depending on the runtime coin.
        return None

    def children(self) -> List[BatchOperator]:
        if self._inner is not None:
            return [self._inner]
        return [self.left, self.right]

    # -- decision ---------------------------------------------------------------
    def _decide(self) -> BatchOperator:
        rvars, rcols = materialize(self.right)
        actual = rcols.shape[1]
        q = q_error(self.est_build, float(actual))
        self.stats.extra["adaptive_qerror"] = round(q, 2)
        # Only an *under*-estimate makes the planned sort more expensive
        # than budgeted; over-estimates mean the sort is cheaper than
        # planned and merge stays the right call.
        sort_cost = actual * max(math.log2(actual), 1.0) if actual else 0.0
        hash_cost = _HASH_BUILD_FACTOR * actual
        switch = (
            q >= QERROR_FLAG
            and actual > self.est_build
            and hash_cost < sort_cost
        )
        if switch:
            self.stats.extra["adaptive_switches"] = 1
            self.stats.detail = f"(?v{self.v}) mode={self.mode} -> hash q={q:.1f}"
            build = MaterializedSource(
                rvars, rcols, None, name="AdaptiveBuild", pool=self.pool
            )
            return HashJoin(
                self.left,
                build,
                self._shared,
                self.mode,
                post_filter=self.post_filter,
                dictionary=self.dictionary,
                pool=self.pool,
                post_program=self.post_program,
                memory_budget=self.memory_budget,
                spill_dir=self.spill_dir,
            )
        self.stats.extra["adaptive_switches"] = 0
        self.stats.detail = f"(?v{self.v}) mode={self.mode} -> merge q={q:.1f}"
        key = rcols[rvars.index(self.v)]
        order = np.argsort(key, kind="stable")
        src = MaterializedSource(
            rvars, rcols[:, order], self.v, name="SortBuffer", pool=self.pool
        )
        return MergeJoin(
            self.left,
            src,
            self.v,
            self.mode,
            post_filter=self.post_filter,
            dictionary=self.dictionary,
            spill_dir=self.spill_dir,
            pool=self.pool,
            post_program=self.post_program,
        )

    def _ensure(self) -> BatchOperator:
        if self._inner is None:
            self._inner = self._decide()
        return self._inner

    # -- execution --------------------------------------------------------------
    def _next(self) -> Optional[ColumnBatch]:
        return self._ensure().next_batch()

    def _close(self) -> None:
        # children() already routes close_tree into self._inner once the
        # decision is made; nothing extra held at this level.
        pass

    def _reset(self) -> None:
        if self._inner is not None:
            self._inner.close()
            self._inner = None
        self.left.reset()
        self.right.reset()
        self.stats.detail = f"(?v{self.v}) mode={self.mode}"
