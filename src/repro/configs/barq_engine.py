"""The paper's own artifact as a config: BARQ engine defaults + the
distributed-join dry-run shapes (launch/engine_dryrun.py reads these).

Not an --arch entry (the engine is the framework's core, not a model);
kept here so every tunable of the reproduction is discoverable in one
place.
"""

from repro.core.executor import EngineConfig

# engine defaults mirroring the paper's production settings (§5.2: max
# batch 512 in Stardog; we default 4096 — CPU vectors amortize further)
BARQ_DEFAULT = EngineConfig(
    engine="barq",
    adaptive_batching=True,
    initial_batch=64,
    max_batch=4096,
    allow_child_skip=True,
)

LEGACY_BASELINE = EngineConfig(engine="legacy")
MIXED_MIGRATION = EngineConfig(engine="mixed")

# distributed-join dry-run shapes (log2 relation sizes x capacity factors)
DIST_JOIN_SHAPES = {
    "edges_2e30_cf2.0": dict(log2_edges=30, cap_factor=2.0),
    "edges_2e30_cf1.25": dict(log2_edges=30, cap_factor=1.25),
    "edges_2e30_cf4.0": dict(log2_edges=30, cap_factor=4.0),
}
