"""dcn-v2 [arXiv:2008.13535]: 13 dense + 26 sparse (embed_dim 16), 3
full-rank cross layers, MLP 1024-1024-512."""
from repro.configs.base import ArchConfig, RECSYS_SHAPES
from repro.models.recsys.dcn import DCNConfig

ARCH = ArchConfig(
    name="dcn-v2",
    kind="recsys",
    model=DCNConfig(),
    reduced_model=DCNConfig(max_table_rows=1000, mlp_dims=(64, 64, 32)),
    shapes=RECSYS_SHAPES,
    source="arXiv:2008.13535",
)
