"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d_model=2048 32H (GQA
kv=4) expert d_ff=768 vocab=151936, MoE 128 experts top-8."""
from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH = ArchConfig(
    name="qwen3-moe-30b-a3b",
    kind="lm",
    model=TransformerConfig(
        name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, d_ff=0, vocab=151936, head_dim=128, qk_norm=True,
        rope_theta=1e6,
        moe=MoEConfig(n_experts=128, top_k=8, d_expert_ff=768),
    ),
    reduced_model=TransformerConfig(
        name="qwen3-moe-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=0, vocab=512, head_dim=32, qk_norm=True, remat="none",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=64),
    ),
    shapes=LM_SHAPES,
    source="hf:Qwen/Qwen3-30B-A3B",
)
