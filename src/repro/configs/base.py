"""Architecture config registry (--arch <id>).

Each assigned architecture has one module exporting ``ARCH`` with the exact
published configuration plus its shape set. ``reduced()`` yields the
smoke-test variant (same family, small dims) run on CPU; the full config is
exercised only by the dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

ARCH_IDS: Tuple[str, ...] = (
    "qwen3-8b",
    "deepseek-7b",
    "command-r-plus-104b",
    "qwen3-moe-30b-a3b",
    "moonshot-v1-16b-a3b",
    "graphsage-reddit",
    "dimenet",
    "gin-tu",
    "gat-cora",
    "dcn-v2",
)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    kind: str  # lm | gnn | recsys
    model: Any  # TransformerConfig | GNNConfig | DCNConfig
    shapes: Dict[str, Dict[str, Any]]
    source: str = ""
    reduced_model: Optional[Any] = None  # smoke-test variant
    notes: str = ""


_MODULES = {aid: f"repro.configs.{aid.replace('-', '_')}" for aid in ARCH_IDS}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).ARCH


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """Every (arch, shape) dry-run cell."""
    out = []
    for aid in ARCH_IDS:
        cfg = get_config(aid)
        for shape in cfg.shapes:
            out.append((aid, shape))
    return tuple(out)


# Shared shape sets -----------------------------------------------------------

LM_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(step="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(step="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(step="decode", seq_len=32768, global_batch=128),
    # long-context decode: served with a sliding-window KV cache
    # (sub-quadratic requirement; DESIGN.md §4) — window 8192
    "long_500k": dict(step="decode", seq_len=524288, global_batch=1, window=8192),
}

GNN_SHAPES: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": dict(
        step="gnn_full", n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7
    ),
    "minibatch_lg": dict(
        step="gnn_minibatch",
        n_graph_nodes=232965,
        n_graph_edges=114615892,
        batch_nodes=1024,
        fanouts=(15, 10),
        d_feat=602,
        n_classes=41,
    ),
    "ogb_products": dict(
        step="gnn_full", n_nodes=2449029, n_edges=61859140, d_feat=100, n_classes=47
    ),
    "molecule": dict(
        step="gnn_molecule",
        n_nodes=30,
        n_edges=64,
        batch=128,
        d_feat=16,
        n_classes=16,
    ),
}

RECSYS_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(step="recsys_train", batch=65536),
    "serve_p99": dict(step="recsys_serve", batch=512),
    "serve_bulk": dict(step="recsys_serve", batch=262144),
    "retrieval_cand": dict(step="recsys_retrieval", batch=1, n_candidates=1000000),
}
