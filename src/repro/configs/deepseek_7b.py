"""deepseek-7b [arXiv:2401.02954]: 30L d_model=4096 32H (GQA kv=32 = MHA)
d_ff=11008 vocab=102400 — llama architecture."""
from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.transformer import TransformerConfig

ARCH = ArchConfig(
    name="deepseek-7b",
    kind="lm",
    model=TransformerConfig(
        name="deepseek-7b", n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab=102400, head_dim=128, qk_norm=False, rope_theta=1e4,
    ),
    reduced_model=TransformerConfig(
        name="deepseek-7b-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=352, vocab=512, head_dim=32, remat="none",
    ),
    shapes=LM_SHAPES,
    source="arXiv:2401.02954",
)
