"""gat-cora [arXiv:1710.10903]: 2L d_hidden=8 n_heads=8 attention aggregator."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.models import GNNConfig

ARCH = ArchConfig(
    name="gat-cora",
    kind="gnn",
    model=GNNConfig(name="gat-cora", kind="gat", n_layers=2, d_hidden=8,
                    n_heads=8, aggregator="attn"),
    reduced_model=GNNConfig(name="gat-smoke", kind="gat", n_layers=2, d_hidden=8,
                            n_heads=4, aggregator="attn"),
    shapes=GNN_SHAPES,
    source="arXiv:1710.10903",
)
