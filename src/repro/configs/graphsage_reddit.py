"""graphsage-reddit [arXiv:1706.02216]: 2L d_hidden=128 mean aggregator,
sample sizes 25-10 (minibatch_lg uses the assigned 15-10 fanout)."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.models import GNNConfig

ARCH = ArchConfig(
    name="graphsage-reddit",
    kind="gnn",
    model=GNNConfig(name="graphsage-reddit", kind="graphsage", n_layers=2,
                    d_hidden=128, aggregator="mean"),
    reduced_model=GNNConfig(name="graphsage-smoke", kind="graphsage", n_layers=2,
                            d_hidden=16, aggregator="mean"),
    shapes=GNN_SHAPES,
    source="arXiv:1706.02216",
)
