"""gin-tu [arXiv:1810.00826]: 5L d_hidden=64 sum aggregator, learnable eps."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.models import GNNConfig

ARCH = ArchConfig(
    name="gin-tu",
    kind="gnn",
    model=GNNConfig(name="gin-tu", kind="gin", n_layers=5, d_hidden=64,
                    aggregator="sum"),
    reduced_model=GNNConfig(name="gin-smoke", kind="gin", n_layers=3, d_hidden=16,
                            aggregator="sum"),
    shapes=GNN_SHAPES,
    source="arXiv:1810.00826",
)
