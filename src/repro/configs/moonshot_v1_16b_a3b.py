"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d_model=2048
16H (kv=16 = MHA) expert d_ff=1408 vocab=163840, MoE 64 experts top-6."""
from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

ARCH = ArchConfig(
    name="moonshot-v1-16b-a3b",
    kind="lm",
    model=TransformerConfig(
        name="moonshot-v1-16b-a3b", n_layers=48, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=0, vocab=163840, head_dim=128, qk_norm=False,
        rope_theta=5e4,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert_ff=1408),
    ),
    reduced_model=TransformerConfig(
        name="moonshot-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=512, head_dim=32, remat="none",
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=96),
    ),
    shapes=LM_SHAPES,
    source="hf:moonshotai/Moonlight-16B-A3B",
)
