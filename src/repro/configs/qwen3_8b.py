"""qwen3-8b [hf:Qwen/Qwen3-8B]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA."""
from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.transformer import TransformerConfig

ARCH = ArchConfig(
    name="qwen3-8b",
    kind="lm",
    model=TransformerConfig(
        name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=12288, vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    ),
    reduced_model=TransformerConfig(
        name="qwen3-8b-smoke", n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=384, vocab=512, head_dim=32, qk_norm=True, remat="none",
    ),
    shapes=LM_SHAPES,
    source="hf:Qwen/Qwen3-8B",
)
