from repro.configs.base import ARCH_IDS, ArchConfig, all_cells, get_config  # noqa: F401
