"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01 family]: 64L
d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000 — GQA, no-bias."""
from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.transformer import TransformerConfig

ARCH = ArchConfig(
    name="command-r-plus-104b",
    kind="lm",
    model=TransformerConfig(
        name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
        n_kv_heads=8, d_ff=33792, vocab=256000, head_dim=128, qk_norm=False,
        rope_theta=1e4,
    ),
    reduced_model=TransformerConfig(
        name="command-r-smoke", n_layers=2, d_model=192, n_heads=6, n_kv_heads=2,
        d_ff=512, vocab=512, head_dim=32, remat="none",
    ),
    shapes=LM_SHAPES,
    source="hf:CohereForAI/c4ai-command-r-plus",
)
