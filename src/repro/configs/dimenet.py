"""dimenet [arXiv:2003.03123]: 6 interaction blocks, d_hidden=128,
n_bilinear=8, n_spherical=7, n_radial=6. Triplet lists are capped per shape
(static-shape budget; DESIGN.md §4)."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.models import GNNConfig

ARCH = ArchConfig(
    name="dimenet",
    kind="gnn",
    model=GNNConfig(name="dimenet", kind="dimenet", n_layers=6, d_hidden=128,
                    n_bilinear=8, n_spherical=7, n_radial=6),
    reduced_model=GNNConfig(name="dimenet-smoke", kind="dimenet", n_layers=2,
                            d_hidden=32, n_bilinear=4, n_spherical=3, n_radial=4),
    shapes=GNN_SHAPES,
    source="arXiv:2003.03123",
)
