"""Report generators: experiments/dryrun/*.json -> roofline markdown
tables for EXPERIMENTS.md, BENCH_PR*.json -> engine tables including
the property-path frontier metrics (rounds, dedup ratio, pool traffic)
emitted by the §8 subsystem (``--bench BENCH_PR2.json``), and the query
telemetry report (DESIGN.md §13): ``--query q6`` / ``--sparql '...'``
runs one query on a generated workload store and prints the whole
observability surface in one place — EXPLAIN, EXPLAIN ANALYZE (actual
vs estimated rows, MISEST flags at q-error >= 4), lifecycle span
timings, the per-query kernel attribution table from the scoped
KernelLedger, and optionally the Perfetto-loadable Chrome-trace JSON
(``--trace out.json``). The structures printed are the same ones
benchmarks/run.py's telemetry smoke and serve.metrics consume.

PR 8 adds the engine-free workload views (DESIGN.md §14): ``--metrics
saved_registry.json`` pretty-prints a saved MetricsRegistry snapshot
(request/latency/plan-cache/kernel/pool tables), and ``--workload-report
workload.jsonl`` renders a saved WorkloadRepository — top fingerprints by
total wall time, the q-error leaderboard, and the regression list. Both
read files only; no store is built and no engine runs.

    PYTHONPATH=src python -m repro.launch.report --query q6 --trace q6.json
    PYTHONPATH=src python -m repro.launch.report --sparql 'SELECT ?a { ... }'
    PYTHONPATH=src python -m repro.launch.report --metrics metrics.json
    PYTHONPATH=src python -m repro.launch.report --workload-report wl.jsonl
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List


def load(out_dir: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _f(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def _gb(x: float) -> str:
    return f"{x / 1e9:.2f}"


def roofline_table(recs: List[Dict], mesh: str, tag_filter: str = "") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "step LB | useful/HLO | temp GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        rt = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append(
            "| {arch} | {shape} | {c} | {m} | {k} | **{dom}** | {lb} | {ur} | {tmp} | {cs} |".format(
                arch=r["arch"],
                shape=r["shape"],
                c=_f(rt["compute_s"]),
                m=_f(rt["memory_s"]),
                k=_f(rt["collective_s"]),
                dom=rt["dominant"],
                lb=_f(rt["step_time_lower_bound_s"]),
                ur=f"{ratio:.2f}" if ratio else "—",
                tmp=_gb(r["memory"]["temp_bytes"]),
                cs=r["compile_s"],
            )
        )
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    fail = [r for r in recs if r.get("status") != "ok"]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    lines = [
        f"cells ok: {len(ok)}, failed: {len(fail)}",
        f"dominant-term distribution: {doms}",
    ]
    for r in fail:
        lines.append(f"FAILED {r['arch']} x {r['shape']} x {r['mesh']}: {r.get('error')}")
    return "\n".join(lines)


def _derived_dict(derived: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def path_metrics_table(bench_json: str) -> str:
    """Markdown table of the property-path rows in a BENCH_PR*.json:
    per-operator frontier rounds, dedup ratio and pool alloc/reuse traffic
    next to the row-baseline speedup (DESIGN.md §8)."""
    with open(bench_json) as f:
        report = json.load(f)
    rows = [
        "| bench | ms/call | pairs | rounds | dedup ratio | pool alloc/reuse | speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for suite in report.values():
        for rec in suite:
            if not str(rec.get("name", "")).startswith("path_"):
                continue
            d = _derived_dict(str(rec.get("derived", "")))
            rows.append(
                "| {name} | {ms:.1f} | {pairs} | {rounds} | {dedup} | {pool} | {sp} |".format(
                    name=rec["name"],
                    ms=float(rec["us_per_call"]) / 1e3,
                    pairs=d.get("pairs", "—"),
                    rounds=d.get("rounds", "—"),
                    dedup=d.get("dedup_ratio", "—"),
                    pool=(
                        f"{d['pool_alloc']}/{d['pool_reuse']}"
                        if "pool_alloc" in d
                        else "—"
                    ),
                    sp=d.get("speedup_vs_row", "—"),
                )
            )
    return "\n".join(rows)


def kernel_table(ledger) -> str:
    """Fixed-width per-kernel attribution table from a KernelLedger
    (dispatch counts + wall-ms by kernel and backend, DESIGN.md §13)."""
    rows = []
    for (name, backend), count in sorted(ledger.backend_counts.items()):
        wall_ms = ledger.backend_wall_s.get((name, backend), 0.0) * 1e3
        rows.append((name, backend, count, wall_ms))
    if not rows:
        return "  (no kernel dispatches recorded)"
    total_ms = sum(r[3] for r in rows) or 1e-9
    lines = [f"  {'kernel':<18} {'backend':<8} {'calls':>7} "
             f"{'wall_ms':>9} {'share':>6}"]
    for name, backend, count, wall_ms in rows:
        lines.append(f"  {name:<18} {backend:<8} {count:>7} "
                     f"{wall_ms:>9.3f} {wall_ms / total_ms:>5.1%}")
    lines.append(f"  {'total':<18} {'':<8} {sum(r[2] for r in rows):>7} "
                 f"{total_ms:>9.3f}")
    return "\n".join(lines)


def span_table(trace) -> str:
    lines = []
    for name, _cat, _t0, dur, args in trace.spans:
        extra = f"  {args}" if args else ""
        lines.append(f"  {name:<12} {dur * 1e3:>9.3f} ms{extra}")
    return "\n".join(lines) if lines else "  (no spans)"


def metrics_report(path: str) -> str:
    """Pretty-print a saved MetricsRegistry snapshot (``registry.save()``
    output or a server's ``metrics_snapshot()`` JSON) as fixed-width
    tables. File-only: no engine, no store."""
    with open(path) as f:
        snap = json.load(f)
    lines: List[str] = []
    req = snap.get("requests", {})
    lines.append(f"uptime: {snap.get('uptime_s', 0):.1f}s   "
                 f"requests: {req.get('count', 0)}   "
                 f"rows: {req.get('rows', 0)}   "
                 f"errors: {req.get('errors', 0)}   "
                 f"qps: {req.get('qps', 0)}")
    lines.append(f"latency: mean {req.get('mean_ms', 0):.3f} ms   "
                 f"p50 {req.get('p50_ms', 0):.3f} ms   "
                 f"p99 {req.get('p99_ms', 0):.3f} ms")
    pc = snap.get("plan_cache", {})
    lines.append(f"plan cache: {pc.get('hits', 0)} hits / "
                 f"{pc.get('misses', 0)} misses "
                 f"(hit rate {pc.get('hit_rate', 0.0):.1%})")
    hist = snap.get("latency_hist", {})
    if hist.get("count"):
        lines.append("\nlatency histogram (cumulative):")
        for le, c in hist.get("buckets", {}).items():
            if c:
                lines.append(f"  le {le:>8}s {c:>8}")
    by_backend = snap.get("kernels", {}).get("by_backend", {})
    if by_backend:
        wall = snap.get("kernels", {}).get("by_backend_wall_ms", {})
        lines.append("\nkernel attribution:")
        lines.append(f"  {'kernel/backend':<28} {'calls':>8} {'wall_ms':>10}")
        for k, c in sorted(by_backend.items()):
            lines.append(f"  {k:<28} {c:>8} {wall.get(k, 0.0):>10.3f}")
    pool = snap.get("pool", {})
    if pool:
        lines.append("\npool events: " + ", ".join(
            f"{k}={v}" for k, v in sorted(pool.items())))
    return "\n".join(lines)


def workload_report(path: str, top_n: int = 15) -> str:
    """Render a saved WorkloadRepository JSONL: top fingerprints by wall
    time, the q-error leaderboard, and recent latency regressions. Loads
    into a fresh repository (exercising the same merge path a restarted
    server uses) — no engine runs."""
    from repro.serve.workload_repo import WorkloadRepository

    repo = WorkloadRepository()
    n = repo.load(path)
    lines: List[str] = [
        f"workload repository: {n} fingerprints, "
        f"{len(repo.feedback.snapshot())} feedback entries",
    ]

    def _ex(rec: dict) -> str:
        ex = " ".join(str(rec.get("example", "")).split())
        return ex[:46] + "…" if len(ex) > 47 else ex

    lines.append("\ntop fingerprints by total wall time:")
    lines.append(f"  {'fingerprint':<18} {'n':>6} {'wall_s':>9} "
                 f"{'mean_ms':>9} {'p99_ms':>9} {'max_q':>7}  example")
    for rec in repo.top_by_wall(top_n):
        lines.append(
            f"  {rec['fingerprint'][:16]:<18} {rec['n']:>6} "
            f"{rec['wall_s']:>9.3f} {rec['mean_s'] * 1e3:>9.3f} "
            f"{rec['p99_s'] * 1e3:>9.3f} {rec['max_q_error']:>7.2f}  "
            f"{_ex(rec)}"
        )
    leaderboard = repo.qerror_leaderboard(top_n)
    if leaderboard:
        lines.append("\nq-error leaderboard (worst plan-node misestimate):")
        lines.append(f"  {'fingerprint':<18} {'max_q':>8} {'n':>6}  example")
        for rec in leaderboard:
            lines.append(f"  {rec['fingerprint'][:16]:<18} "
                         f"{rec['max_q_error']:>8.2f} {rec['n']:>6}  {_ex(rec)}")
    if repo.regressions:
        lines.append("\nlatency regressions (latest first):")
        lines.append(f"  {'fingerprint':<18} {'latency_ms':>11} "
                     f"{'baseline_p99_ms':>16} {'factor':>7}")
        for rec in list(repo.regressions)[::-1]:
            lines.append(
                f"  {str(rec.get('fingerprint', ''))[:16]:<18} "
                f"{rec.get('latency_s', 0.0) * 1e3:>11.3f} "
                f"{rec.get('baseline_p99_s', 0.0) * 1e3:>16.3f} "
                f"{rec.get('factor', 0.0):>7.2f}"
            )
    else:
        lines.append("\nno latency regressions recorded")
    return "\n".join(lines)


def query_report(args, parser) -> int:
    """The --query/--sparql mode: one query, full telemetry surface."""
    from repro.core import Engine, EngineConfig
    from repro.data import LSQB_QUERIES, generate_social_graph

    if args.sparql:
        query, label = args.sparql, "adhoc"
    else:
        if args.query not in LSQB_QUERIES:
            parser.error(f"unknown LSQB query {args.query!r} "
                         f"(have: {', '.join(sorted(LSQB_QUERIES))})")
        query, label = LSQB_QUERIES[args.query], args.query

    store, meta = generate_social_graph(scale=args.scale)
    engine = Engine(store, EngineConfig(engine=args.engine))
    res = engine.execute(query)
    trace = res.trace

    if args.json:
        doc = trace.summary()
        doc["pool"] = res.pool_delta()
        doc["rows"] = res.n_rows
        print(json.dumps(doc, indent=2))
    else:
        print(f"query {label} on {meta['n_triples']} triples "
              f"({args.engine} engine): {res.n_rows} rows\n")
        print("plan (EXPLAIN):")
        print(engine.explain(query))
        print("\noperators (EXPLAIN ANALYZE):")
        print(res.explain_analyze())
        print("\nlifecycle spans:")
        print(span_table(trace))
        print("\nkernel attribution:")
        print(kernel_table(trace.ledger))
        if res.pool_delta():
            print("\npool delta:", res.pool_delta())

    if args.trace:
        trace.save_chrome_trace(args.trace)
        print(f"\nwrote {args.trace} — open in ui.perfetto.dev",
              file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--bench", default=None, metavar="BENCH_JSON",
                    help="print the property-path metrics table instead")
    ap.add_argument("--query", default=None,
                    help="telemetry report for an LSQB query (q1..q9)")
    ap.add_argument("--sparql", default=None,
                    help="telemetry report for raw SPARQL text")
    ap.add_argument("--scale", type=float, default=0.05,
                    help="social-graph scale factor for --query/--sparql")
    ap.add_argument("--engine", default="barq",
                    choices=("barq", "mixed", "legacy"))
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the query's Chrome-trace JSON here")
    ap.add_argument("--json", action="store_true",
                    help="emit the query trace summary as JSON")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="pretty-print a saved MetricsRegistry JSON")
    ap.add_argument("--workload-report", default=None, metavar="PATH",
                    help="render a saved WorkloadRepository JSONL")
    args = ap.parse_args()
    if args.metrics:
        print(metrics_report(args.metrics))
        return
    if args.workload_report:
        print(workload_report(args.workload_report))
        return
    if args.query or args.sparql:
        raise SystemExit(query_report(args, ap))
    if args.bench:
        print(path_metrics_table(args.bench))
        return
    recs = [r for r in load(args.out) if "__" not in (r.get("tag") or "")]
    print(summary(recs))
    print()
    print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
