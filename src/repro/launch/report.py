"""Report generators: experiments/dryrun/*.json -> roofline markdown
tables for EXPERIMENTS.md, BENCH_PR*.json -> engine tables including
the property-path frontier metrics (rounds, dedup ratio, pool traffic)
emitted by the §8 subsystem (``--bench BENCH_PR2.json``), and the query
telemetry report (DESIGN.md §13): ``--query q6`` / ``--sparql '...'``
runs one query on a generated workload store and prints the whole
observability surface in one place — EXPLAIN, EXPLAIN ANALYZE (actual
vs estimated rows, MISEST flags at q-error >= 4), lifecycle span
timings, the per-query kernel attribution table from the scoped
KernelLedger, and optionally the Perfetto-loadable Chrome-trace JSON
(``--trace out.json``). The structures printed are the same ones
benchmarks/run.py's telemetry smoke and serve.metrics consume.

    PYTHONPATH=src python -m repro.launch.report --query q6 --trace q6.json
    PYTHONPATH=src python -m repro.launch.report --sparql 'SELECT ?a { ... }'
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List


def load(out_dir: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _f(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def _gb(x: float) -> str:
    return f"{x / 1e9:.2f}"


def roofline_table(recs: List[Dict], mesh: str, tag_filter: str = "") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "step LB | useful/HLO | temp GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        rt = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append(
            "| {arch} | {shape} | {c} | {m} | {k} | **{dom}** | {lb} | {ur} | {tmp} | {cs} |".format(
                arch=r["arch"],
                shape=r["shape"],
                c=_f(rt["compute_s"]),
                m=_f(rt["memory_s"]),
                k=_f(rt["collective_s"]),
                dom=rt["dominant"],
                lb=_f(rt["step_time_lower_bound_s"]),
                ur=f"{ratio:.2f}" if ratio else "—",
                tmp=_gb(r["memory"]["temp_bytes"]),
                cs=r["compile_s"],
            )
        )
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    fail = [r for r in recs if r.get("status") != "ok"]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    lines = [
        f"cells ok: {len(ok)}, failed: {len(fail)}",
        f"dominant-term distribution: {doms}",
    ]
    for r in fail:
        lines.append(f"FAILED {r['arch']} x {r['shape']} x {r['mesh']}: {r.get('error')}")
    return "\n".join(lines)


def _derived_dict(derived: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def path_metrics_table(bench_json: str) -> str:
    """Markdown table of the property-path rows in a BENCH_PR*.json:
    per-operator frontier rounds, dedup ratio and pool alloc/reuse traffic
    next to the row-baseline speedup (DESIGN.md §8)."""
    with open(bench_json) as f:
        report = json.load(f)
    rows = [
        "| bench | ms/call | pairs | rounds | dedup ratio | pool alloc/reuse | speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for suite in report.values():
        for rec in suite:
            if not str(rec.get("name", "")).startswith("path_"):
                continue
            d = _derived_dict(str(rec.get("derived", "")))
            rows.append(
                "| {name} | {ms:.1f} | {pairs} | {rounds} | {dedup} | {pool} | {sp} |".format(
                    name=rec["name"],
                    ms=float(rec["us_per_call"]) / 1e3,
                    pairs=d.get("pairs", "—"),
                    rounds=d.get("rounds", "—"),
                    dedup=d.get("dedup_ratio", "—"),
                    pool=(
                        f"{d['pool_alloc']}/{d['pool_reuse']}"
                        if "pool_alloc" in d
                        else "—"
                    ),
                    sp=d.get("speedup_vs_row", "—"),
                )
            )
    return "\n".join(rows)


def kernel_table(ledger) -> str:
    """Fixed-width per-kernel attribution table from a KernelLedger
    (dispatch counts + wall-ms by kernel and backend, DESIGN.md §13)."""
    rows = []
    for (name, backend), count in sorted(ledger.backend_counts.items()):
        wall_ms = ledger.backend_wall_s.get((name, backend), 0.0) * 1e3
        rows.append((name, backend, count, wall_ms))
    if not rows:
        return "  (no kernel dispatches recorded)"
    total_ms = sum(r[3] for r in rows) or 1e-9
    lines = [f"  {'kernel':<18} {'backend':<8} {'calls':>7} "
             f"{'wall_ms':>9} {'share':>6}"]
    for name, backend, count, wall_ms in rows:
        lines.append(f"  {name:<18} {backend:<8} {count:>7} "
                     f"{wall_ms:>9.3f} {wall_ms / total_ms:>5.1%}")
    lines.append(f"  {'total':<18} {'':<8} {sum(r[2] for r in rows):>7} "
                 f"{total_ms:>9.3f}")
    return "\n".join(lines)


def span_table(trace) -> str:
    lines = []
    for name, _cat, _t0, dur, args in trace.spans:
        extra = f"  {args}" if args else ""
        lines.append(f"  {name:<12} {dur * 1e3:>9.3f} ms{extra}")
    return "\n".join(lines) if lines else "  (no spans)"


def query_report(args, parser) -> int:
    """The --query/--sparql mode: one query, full telemetry surface."""
    from repro.core import Engine, EngineConfig
    from repro.data import LSQB_QUERIES, generate_social_graph

    if args.sparql:
        query, label = args.sparql, "adhoc"
    else:
        if args.query not in LSQB_QUERIES:
            parser.error(f"unknown LSQB query {args.query!r} "
                         f"(have: {', '.join(sorted(LSQB_QUERIES))})")
        query, label = LSQB_QUERIES[args.query], args.query

    store, meta = generate_social_graph(scale=args.scale)
    engine = Engine(store, EngineConfig(engine=args.engine))
    res = engine.execute(query)
    trace = res.trace

    if args.json:
        doc = trace.summary()
        doc["pool"] = res.pool_delta()
        doc["rows"] = res.n_rows
        print(json.dumps(doc, indent=2))
    else:
        print(f"query {label} on {meta['n_triples']} triples "
              f"({args.engine} engine): {res.n_rows} rows\n")
        print("plan (EXPLAIN):")
        print(engine.explain(query))
        print("\noperators (EXPLAIN ANALYZE):")
        print(res.explain_analyze())
        print("\nlifecycle spans:")
        print(span_table(trace))
        print("\nkernel attribution:")
        print(kernel_table(trace.ledger))
        if res.pool_delta():
            print("\npool delta:", res.pool_delta())

    if args.trace:
        trace.save_chrome_trace(args.trace)
        print(f"\nwrote {args.trace} — open in ui.perfetto.dev",
              file=sys.stderr)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--bench", default=None, metavar="BENCH_JSON",
                    help="print the property-path metrics table instead")
    ap.add_argument("--query", default=None,
                    help="telemetry report for an LSQB query (q1..q9)")
    ap.add_argument("--sparql", default=None,
                    help="telemetry report for raw SPARQL text")
    ap.add_argument("--scale", type=float, default=0.05,
                    help="social-graph scale factor for --query/--sparql")
    ap.add_argument("--engine", default="barq",
                    choices=("barq", "mixed", "legacy"))
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write the query's Chrome-trace JSON here")
    ap.add_argument("--json", action="store_true",
                    help="emit the query trace summary as JSON")
    args = ap.parse_args()
    if args.query or args.sparql:
        raise SystemExit(query_report(args, ap))
    if args.bench:
        print(path_metrics_table(args.bench))
        return
    recs = [r for r in load(args.out) if "__" not in (r.get("tag") or "")]
    print(summary(recs))
    print()
    print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
