"""Report generators: experiments/dryrun/*.json -> roofline markdown
tables for EXPERIMENTS.md, and BENCH_PR*.json -> engine tables including
the property-path frontier metrics (rounds, dedup ratio, pool traffic)
emitted by the §8 subsystem (``--bench BENCH_PR2.json``)."""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(out_dir: str) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _f(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}µs"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def _gb(x: float) -> str:
    return f"{x / 1e9:.2f}"


def roofline_table(recs: List[Dict], mesh: str, tag_filter: str = "") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "step LB | useful/HLO | temp GB/dev | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        rt = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        rows.append(
            "| {arch} | {shape} | {c} | {m} | {k} | **{dom}** | {lb} | {ur} | {tmp} | {cs} |".format(
                arch=r["arch"],
                shape=r["shape"],
                c=_f(rt["compute_s"]),
                m=_f(rt["memory_s"]),
                k=_f(rt["collective_s"]),
                dom=rt["dominant"],
                lb=_f(rt["step_time_lower_bound_s"]),
                ur=f"{ratio:.2f}" if ratio else "—",
                tmp=_gb(r["memory"]["temp_bytes"]),
                cs=r["compile_s"],
            )
        )
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    fail = [r for r in recs if r.get("status") != "ok"]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    lines = [
        f"cells ok: {len(ok)}, failed: {len(fail)}",
        f"dominant-term distribution: {doms}",
    ]
    for r in fail:
        lines.append(f"FAILED {r['arch']} x {r['shape']} x {r['mesh']}: {r.get('error')}")
    return "\n".join(lines)


def _derived_dict(derived: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def path_metrics_table(bench_json: str) -> str:
    """Markdown table of the property-path rows in a BENCH_PR*.json:
    per-operator frontier rounds, dedup ratio and pool alloc/reuse traffic
    next to the row-baseline speedup (DESIGN.md §8)."""
    with open(bench_json) as f:
        report = json.load(f)
    rows = [
        "| bench | ms/call | pairs | rounds | dedup ratio | pool alloc/reuse | speedup |",
        "|---|---|---|---|---|---|---|",
    ]
    for suite in report.values():
        for rec in suite:
            if not str(rec.get("name", "")).startswith("path_"):
                continue
            d = _derived_dict(str(rec.get("derived", "")))
            rows.append(
                "| {name} | {ms:.1f} | {pairs} | {rounds} | {dedup} | {pool} | {sp} |".format(
                    name=rec["name"],
                    ms=float(rec["us_per_call"]) / 1e3,
                    pairs=d.get("pairs", "—"),
                    rounds=d.get("rounds", "—"),
                    dedup=d.get("dedup_ratio", "—"),
                    pool=(
                        f"{d['pool_alloc']}/{d['pool_reuse']}"
                        if "pool_alloc" in d
                        else "—"
                    ),
                    sp=d.get("speedup_vs_row", "—"),
                )
            )
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--bench", default=None, metavar="BENCH_JSON",
                    help="print the property-path metrics table instead")
    args = ap.parse_args()
    if args.bench:
        print(path_metrics_table(args.bench))
        return
    recs = [r for r in load(args.out) if "__" not in (r.get("tag") or "")]
    print(summary(recs))
    print()
    print(roofline_table(recs, args.mesh))


if __name__ == "__main__":
    main()
