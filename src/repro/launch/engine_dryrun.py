import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Dry-run of the BARQ engine's own scale-out path: the distributed
hash-partitioned join (core/distributed.py) lowered on the production
meshes — the paper's technique as the workload, alongside the assigned
architectures.

    PYTHONPATH=src python -m repro.launch.engine_dryrun [--edges 30] \
        [--cap-factor 2.0] [--mesh single]
"""  # noqa: E402

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import distributed as D
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes, roofline_terms


def run(log2_edges: int, cap_factor: float, multi_pod: bool, out_dir: str,
        tag: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    n = 1 << log2_edges
    prod = make_production_mesh(multi_pod=multi_pod)
    # the engine runs under a flat view of the same chips (one exchange
    # group spanning pods — DESIGN.md §2.1)
    mesh = D.engine_mesh(prod.devices.reshape(-1))
    chips = int(mesh.devices.size)
    fn = D.make_join_count(mesh, cap_factor=cap_factor)
    args = (
        jax.ShapeDtypeStruct((2, n), jnp.int32),
        jax.ShapeDtypeStruct((2, n), jnp.int32),
    )
    t0 = time.time()
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = collective_bytes(compiled.as_text())
    terms = roofline_terms(
        float(cost.get("flops", 0)),
        float(cost.get("bytes accessed", 0)),
        float(coll["total_bytes"]),
    )
    rec = dict(
        arch="barq-dist-join",
        shape=f"edges_2e{log2_edges}_cf{cap_factor}",
        mesh=mesh_name,
        status="ok",
        n_chips=chips,
        compile_s=round(time.time() - t0, 2),
        cost=dict(
            flops_per_device=float(cost.get("flops", 0)),
            bytes_per_device=float(cost.get("bytes accessed", 0)),
        ),
        memory=dict(
            argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
            temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        ),
        collectives=coll,
        roofline=terms,
    )
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    with open(os.path.join(
            out_dir, f"barq-dist-join__{rec['shape']}__{mesh_name}{suffix}.json"),
            "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edges", type=int, default=30, help="log2 edge count")
    ap.add_argument("--cap-factor", type=float, default=2.0)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for m in meshes:
        rec = run(args.edges, args.cap_factor, m == "multi", args.out, args.tag)
        rt = rec["roofline"]
        print(
            f"barq-dist-join 2^{args.edges} edges x {m}: "
            f"compute={rt['compute_s']:.3e}s memory={rt['memory_s']:.3e}s "
            f"collective={rt['collective_s']:.3e}s dominant={rt['dominant']} "
            f"(compile {rec['compile_s']}s)"
        )


if __name__ == "__main__":
    main()
