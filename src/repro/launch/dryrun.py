import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k --mesh single --out experiments/dryrun

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per-cell results land in <out>/<arch>__<shape>__<mesh>.json; failures are
recorded with the exception text (a failing cell is a bug in the sharding
config — the point of the exercise). --all runs each cell in a fresh
subprocess so XLA compile memory is released between cells.
"""  # noqa: E402

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax

from repro import compat

from repro.configs import ARCH_IDS, all_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    HBM_BW, LINK_BW, PEAK_FLOPS, collective_bytes, model_flops, roofline_terms,
)
from repro.launch.steps import build_step


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str,
             skip_existing: bool = False, overrides: dict = None,
             tag: str = "") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        out_dir, f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json"
    )
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            prior = json.load(f)
        if prior.get("status") == "ok":
            return prior

    arch = get_config(arch_id)
    sh0 = dict(arch.shapes[shape_name])
    sh0.update(overrides or {})
    import dataclasses as _dc

    arch = _dc.replace(arch, shapes={**arch.shapes, shape_name: sh0})
    record = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "failed",
    }
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = int(mesh.devices.size)

        def compile_once(arch_):
            bundle = build_step(arch_, shape_name, mesh)
            with compat.set_mesh(mesh):
                jitted = jax.jit(
                    bundle.fn,
                    in_shardings=bundle.in_shardings,
                    out_shardings=bundle.out_shardings,
                    donate_argnums=bundle.donate_argnums,
                )
                lowered = jitted.lower(*bundle.abstract_args)
                compiled = lowered.compile()
                mem_ = compiled.memory_analysis()
                cost_ = compiled.cost_analysis()
                hlo_ = compiled.as_text()
            return bundle, mem_, cost_, collective_bytes(hlo_)

        bundle, mem, cost, coll = compile_once(arch)
        t_compile_total = time.time() - t0
        t_lower, t_compile = 0.0, t_compile_total

        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        coll_dev = float(coll["total_bytes"])

        # lax.scan bodies are cost-analysed ONCE, not x trip count. For LM
        # cells, compile L=1 and L=2 variants and extrapolate the per-layer
        # deltas exactly (all body terms are linear in n_layers). See
        # EXPERIMENTS.md §Roofline methodology.
        extrapolated = False
        if arch.kind == "lm":
            L = arch.model.n_layers
            costs, colls = {}, {}
            for l_small in (1, 2):
                # unroll the (tiny) layer stack so per-layer costs are in
                # the analysed HLO rather than inside a once-counted scan
                arch_s = _dc.replace(
                    arch,
                    model=_dc.replace(arch.model, n_layers=l_small),
                    shapes={
                        **arch.shapes,
                        shape_name: {**arch.shapes[shape_name],
                                     "unroll_layers": True},
                    },
                )
                _, _, cost_s, coll_s = compile_once(arch_s)
                costs[l_small] = cost_s
                colls[l_small] = coll_s

            def extrap(f1: float, f2: float) -> float:
                per_layer = max(f2 - f1, 0.0)
                return f1 + per_layer * (L - 1)

            flops_dev = extrap(
                float(costs[1].get("flops", 0.0)), float(costs[2].get("flops", 0.0))
            )
            bytes_dev = extrap(
                float(costs[1].get("bytes accessed", 0.0)),
                float(costs[2].get("bytes accessed", 0.0)),
            )
            coll_dev = extrap(
                float(colls[1]["total_bytes"]), float(colls[2]["total_bytes"])
            )
            coll = {
                "per_kind_bytes": {
                    k: int(extrap(colls[1]["per_kind_bytes"][k],
                                  colls[2]["per_kind_bytes"][k]))
                    for k in colls[1]["per_kind_bytes"]
                },
                "per_kind_counts": {
                    k: int(extrap(colls[1]["per_kind_counts"][k],
                                  colls[2]["per_kind_counts"][k]))
                    for k in colls[1]["per_kind_counts"]
                },
                "total_bytes": coll_dev,
            }
            extrapolated = True
        terms = roofline_terms(flops_dev, bytes_dev, coll_dev)

        sh = arch.shapes[shape_name]
        if arch.kind == "lm":
            if sh["step"] == "train":
                d = sh["global_batch"] * sh["seq_len"]
                training = True
            elif sh["step"] == "prefill":
                d = sh["global_batch"] * sh["seq_len"]
                training = False
            else:
                d = sh["global_batch"]  # one token per request
                training = False
            useful = model_flops("lm", arch.model, sh, d, training)
        else:
            useful = None

        record.update(
            status="ok",
            description=bundle.description,
            n_chips=n_chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            scan_body_extrapolated=extrapolated,
            overrides=overrides or {},
            memory=dict(
                argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
                output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
                temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
                code_bytes=int(getattr(mem, "generated_code_size_in_bytes", 0)),
            ),
            cost=dict(
                flops_per_device=flops_dev,
                bytes_per_device=bytes_dev,
                global_flops=flops_dev * n_chips,
            ),
            collectives=coll,
            roofline=terms,
            hw=dict(peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, link_bw=LINK_BW),
        )
        if useful is not None:
            record["model_flops_global"] = useful
            gf = flops_dev * n_chips
            record["useful_flops_ratio"] = useful / gf if gf else None
    except Exception as e:  # noqa: BLE001
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["wall_s"] = round(time.time() - t0, 2)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="shape override k=v (perf iteration knobs)")
    ap.add_argument("--tag", default="", help="suffix for the output json")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        cells = all_cells()
        failures = 0
        for arch_id, shape in cells:
            for m in meshes:
                mesh_name = m
                path = os.path.join(args.out, f"{arch_id}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    if rec.get("status") == "ok":
                        print(f"[skip] {arch_id} x {shape} x {m}: ok")
                        continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch_id, "--shape", shape, "--mesh", m,
                    "--out", args.out,
                ]
                r = subprocess.run(cmd, capture_output=True, text=True)
                try:
                    with open(path) as f:
                        rec = json.load(f)
                    ok = rec["status"] == "ok"
                except FileNotFoundError:
                    ok, rec = False, {"error": r.stderr[-500:]}
                failures += 0 if ok else 1
                msg = (
                    f"compile={rec.get('compile_s')}s dom={rec.get('roofline', {}).get('dominant')}"
                    if ok
                    else rec.get("error", "?")[:200]
                )
                print(f"[{'ok' if ok else 'FAIL'}] {arch_id} x {shape} x {m}: {msg}",
                      flush=True)
        print(f"done; {failures} failures")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape or --all required"
    for m in meshes:
        rec = run_cell(args.arch, args.shape, m == "multi", args.out,
                       args.skip_existing, overrides, args.tag)
        if rec["status"] == "ok":
            rt = rec["roofline"]
            print(
                f"{args.arch} x {args.shape} x {m}: ok "
                f"compile={rec['compile_s']}s "
                f"compute={rt['compute_s']:.3e}s memory={rt['memory_s']:.3e}s "
                f"collective={rt['collective_s']:.3e}s dominant={rt['dominant']}"
            )
            print("memory:", rec["memory"])
        else:
            print(f"{args.arch} x {args.shape} x {m}: FAILED\n{rec.get('traceback', '')}")
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
