"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs REDUCED configs end-to-end on the local device(s) (CPU here) with the
full production substrate: jitted train step, AdamW, async checkpointing,
restart/resume, watchdog. The FULL configs are exercised via the dry-run
(-m repro.launch.dryrun); on a real fleet this same launcher runs them by
pointing --mesh at the production mesh.
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro import compat
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_smoke_mesh
from repro.launch.steps import build_step, _gnn_graph_shape
from repro.models.gnn import models as GNN
from repro.pipeline.data import recsys_batch, token_batch
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.trainer import Trainer, TrainerConfig


def _make_batch_fn(arch, shape_name, bundle, seed, reduced_model):
    sh = arch.shapes[shape_name]
    if arch.kind == "lm":
        b, s = sh["global_batch"], sh["seq_len"]
        vocab = reduced_model.vocab

        def fn(step):
            d = token_batch(seed, step, b, s, vocab)
            return (d["tokens"], d["labels"])

        return fn
    if arch.kind == "gnn":
        gshape = _gnn_graph_shape(arch, shape_name, reduced_model)

        def fn(step):
            g = GNN.make_graph_inputs(gshape, rng_seed=seed + step)
            return (g,)

        return fn
    # recsys
    b = sh["batch"]
    cfg = reduced_model

    def fn(step):
        d = recsys_batch(seed, step, b, cfg.n_dense, cfg.n_sparse,
                         [cfg.table_rows(i) for i in range(cfg.n_sparse)])
        return (d["dense"], d["sparse"], d["labels"])

    return fn


def run(arch_id: str, shape_name: str, steps: int, ckpt_dir: str,
        seed: int = 0, lr: float = 3e-4, log_every: int = 10,
        override_shape: dict = None):
    arch = get_config(arch_id)
    if override_shape:
        shapes = dict(arch.shapes)
        shapes[shape_name] = {**shapes[shape_name], **override_shape}
        import dataclasses as _dc

        arch = _dc.replace(arch, shapes=shapes)
    mesh = make_smoke_mesh()
    opt_cfg = OptimizerConfig(lr=lr, warmup_steps=max(steps // 20, 5),
                              total_steps=steps)
    with compat.set_mesh(mesh):
        bundle = build_step(arch, shape_name, mesh, opt_cfg, use_reduced=True)
        step_jit = jax.jit(bundle.fn, donate_argnums=bundle.donate_argnums)

        reduced = arch.reduced_model
        batch_fn = _make_batch_fn(arch, shape_name, bundle, seed, reduced)

        def init_state():
            if arch.kind == "lm":
                from repro.models.transformer import init_params

                params = init_params(reduced, jax.random.PRNGKey(seed))
            elif arch.kind == "gnn":
                gshape = _gnn_graph_shape(arch, shape_name, reduced)
                params = GNN.init(jax.random.PRNGKey(seed), reduced, gshape)
            else:
                from repro.models.recsys.dcn import init_params as dcn_init

                params = dcn_init(reduced, jax.random.PRNGKey(seed))
            return (params, init_opt_state(params))

        def train_step(state, batch):
            params, opt = state
            out = step_jit(params, opt, *batch)
            params, opt, metrics = out
            return (params, opt), metrics

        trainer = Trainer(
            TrainerConfig(total_steps=steps, ckpt_every=max(steps // 4, 10),
                          ckpt_dir=ckpt_dir, log_every=log_every),
            train_step,
            init_state,
            batch_fn,
        )
        return trainer.run(), trainer


def main():
    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    arch = get_config(args.arch)
    shape = args.shape or next(
        s for s, v in arch.shapes.items()
        if v["step"] in ("train", "gnn_full", "gnn_minibatch", "gnn_molecule",
                         "recsys_train")
    )
    # keep CPU smoke training tractable
    override = None
    if arch.kind == "lm":
        override = {"global_batch": 8, "seq_len": 128}
    elif arch.kind == "recsys":
        override = {"batch": 256}
    elif arch.shapes[shape]["step"] == "gnn_full":
        override = {"n_nodes": 512, "n_edges": 2048, "d_feat": 32, "n_classes": 8}
    elif arch.shapes[shape]["step"] == "gnn_minibatch":
        override = {"batch_nodes": 32, "fanouts": (5, 3), "d_feat": 32,
                    "n_classes": 8}
    elif arch.shapes[shape]["step"] == "gnn_molecule":
        override = {"batch": 8}
    result, trainer = run(args.arch, shape, args.steps, args.ckpt_dir,
                          args.seed, args.lr)
    print("final:", result)
    losses = [m["loss"] for m in trainer.metrics_history]
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")


if __name__ == "__main__":
    main()
