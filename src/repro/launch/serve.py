"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Two modes:
  --mode queries  — batched SPARQL serving through the BARQ engine
                    (the paper's kind of service; QueryServer)
  --mode lm       — continuous-batching LM decode on the reduced config
                    (LMServer; adaptive admission)
"""

from __future__ import annotations

import argparse
import dataclasses

import numpy as np


def serve_queries(requests: int, scale: float) -> None:
    from repro.core import EngineConfig
    from repro.data import (
        BSBM_EXPLORE_TEMPLATES, generate_ecommerce_graph, instantiate_explore,
    )
    from repro.serve.query_server import QueryServer

    store, meta = generate_ecommerce_graph(scale=scale)
    server = QueryServer(store, EngineConfig(engine="barq"))
    rng = np.random.RandomState(0)
    reqs = []
    tpls = list(BSBM_EXPLORE_TEMPLATES.items())
    for _ in range(requests):
        k, tpl = tpls[rng.randint(len(tpls))]
        reqs.append((k, instantiate_explore(tpl, meta, rng)))
    stats = server.run_workload(reqs, warmup=min(10, requests // 10))
    print("query serving:", stats)


def serve_lm(arch_id: str, requests: int) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import transformer as TF
    from repro.serve.lm_server import LMServer, Request

    cfg = dataclasses.replace(get_config(arch_id).reduced_model, remat="none")
    params = TF.init_params(cfg, jax.random.PRNGKey(0))
    server = LMServer(cfg, params, n_slots=4, cache_len=128)
    rng = np.random.RandomState(0)
    for i in range(requests):
        server.submit(Request(
            rid=i,
            prompt=rng.randint(0, cfg.vocab, rng.randint(4, 12)).astype(np.int32),
            max_new=16,
        ))
    import time

    t0 = time.perf_counter()
    out = server.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(v) for v in out.values())
    print(f"lm serving: {len(out)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {server.steps} engine steps)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("queries", "lm"), default="queries")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=100)
    ap.add_argument("--scale", type=float, default=0.1)
    args = ap.parse_args()
    if args.mode == "queries":
        serve_queries(args.requests, args.scale)
    else:
        serve_lm(args.arch, args.requests)


if __name__ == "__main__":
    main()
