"""Roofline-term extraction from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` — XLA reports the
*partitioned per-device* program, so global = per-device × chips, and the
per-chip terms divide by peak directly. collective_bytes is parsed from the
compiled HLO text: the summed output sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op (output
size ~ payload moved per device per step; methodology note in
EXPERIMENTS.md).

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output sizes per collective kind from compiled HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        shape_str, opname = m.group(1), m.group(2)
        # opname like 'all-reduce', 'all-gather-start', ...
        base = opname
        for k in _COLLECTIVES:
            if base == k or base.startswith(k + "-"):
                if base.endswith("-done"):
                    break  # avoid double counting async pairs
                out[k] += _shape_bytes(shape_str)
                counts[k] += 1
                break
    return {
        "per_kind_bytes": out,
        "per_kind_counts": counts,
        "total_bytes": sum(out.values()),
    }


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> Dict[str, float]:
    compute_s = flops_per_dev / PEAK_FLOPS
    memory_s = bytes_per_dev / HBM_BW
    collective_s = coll_bytes_per_dev / LINK_BW
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom.replace("_s", "")
    terms["step_time_lower_bound_s"] = bound
    # fraction of the bound spent on the dominant term's roofline resource
    terms["roofline_fraction"] = (
        max(compute_s, memory_s) / bound if bound > 0 else 0.0
    )
    return terms


def model_flops(arch_kind: str, model, shape: Dict, n_tokens_or_items: int,
                training: bool) -> float:
    """'Useful' model FLOPs: 6·N·D dense / 6·N_active·D MoE for training,
    2·N·D inference (N = params, D = tokens/items processed)."""
    mult = 6.0 if training else 2.0
    if arch_kind == "lm":
        n = model.active_param_count() if model.moe else model.param_count()
        return mult * n * n_tokens_or_items
    # gnn / recsys: use dense-parameter work as the useful-FLOPs proxy
    return mult * n_tokens_or_items
