"""Step builders: (arch, shape, mesh) -> jitted step + abstract inputs +
shardings. Shared by the dry-run (lower/compile on ShapeDtypeStructs), the
trainers and the smoke tests (concrete arrays, 1-device mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import transformer as TF
from repro.models.gnn import models as GNN
from repro.models.recsys import dcn as DCN
from repro.parallel.sharding import MeshAxes, spec
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state

# DimeNet static triplet budgets per shape (DESIGN.md §4)
DIMENET_TRIPLET_CAP = {
    "full_graph_sm": 131072,
    "minibatch_lg": 1048576,
    "ogb_products": 4194304,
    "molecule": 32768,
}


@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run / trainer needs for one (arch, shape) cell."""

    fn: Callable  # positional (state..., inputs...)
    abstract_args: Tuple[Any, ...]  # ShapeDtypeStructs matching fn args
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()
    description: str = ""


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _zero_shard(spec_tree, abs_tree, axes: MeshAxes, mesh: Mesh):
    """ZeRO-1: additionally shard optimizer-moment leaves over the dp axes
    on the first unsharded dim whose size divides the dp degree (§Perf
    memory lever — cuts the 2x fp32 moments to 2x/dp per device at the cost
    of a params all-gather in the update)."""
    dp_size = int(np.prod([mesh.shape[a] for a in axes.dp]))
    dp_entry = axes.resolve("dp")

    dp_names = set(axes.dp)

    def one(s: P, a) -> P:
        entries = list(s) + [None] * (len(a.shape) - len(s))
        for e in entries:  # idempotent: already dp-sharded leaves unchanged
            names = e if isinstance(e, tuple) else (e,)
            if any(n in dp_names for n in names if n):
                return s
        for i, (e, dim) in enumerate(zip(entries, a.shape)):
            if e is None and dim % dp_size == 0 and dim > 0:
                entries[i] = dp_entry
                return P(*entries)
        return s

    return jax.tree.map(one, spec_tree, abs_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _dp_batch_spec(axes: MeshAxes, mesh: Mesh, batch: int, *rest) -> P:
    """Shard batch over dp only when divisible; replicate otherwise
    (batch-1 long-context decode)."""
    dp_size = int(np.prod([mesh.shape[a] for a in axes.dp]))
    lead = axes.resolve("dp") if batch % dp_size == 0 else None
    return P(lead, *rest)


# ---------------------------------------------------------------------------
# LM steps
# ---------------------------------------------------------------------------


def _lm_bundle(arch: ArchConfig, shape_name: str, mesh: Mesh,
               opt_cfg: Optional[OptimizerConfig] = None,
               model_override=None) -> StepBundle:
    axes = MeshAxes.for_mesh(mesh)
    sh = arch.shapes[shape_name]
    cfg: TF.TransformerConfig = model_override or arch.model
    if sh.get("window"):
        cfg = dataclasses.replace(cfg, window=sh["window"])
    for knob in ("unroll_layers", "seq_parallel", "microbatches", "remat"):
        if knob in sh:
            cfg = dataclasses.replace(cfg, **{knob: sh[knob]})
    if "moe_impl" in sh and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, impl=sh["moe_impl"])
        )
    pspecs = TF.param_specs(cfg, axes)
    params_abs = jax.eval_shape(lambda k: TF.init_params(cfg, k), jax.random.PRNGKey(0))
    if sh.get("zero_params") and sh["step"] == "train":
        # FSDP / ZeRO-3: additionally shard the master params over dp; XLA
        # all-gathers each weight at its use sites (collective for memory)
        pspecs = _zero_shard(pspecs, params_abs, axes, mesh)
    params_sh = _named(mesh, pspecs)
    b, s = sh["global_batch"], sh["seq_len"]

    if sh["step"] == "train":
        opt_cfg = opt_cfg or OptimizerConfig()
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        moment_specs = pspecs
        if sh.get("zero_opt"):
            moment_specs = _zero_shard(pspecs, params_abs, axes, mesh)
        opt_specs = {"mu": moment_specs, "nu": moment_specs, "step": P()}
        opt_sh = _named(mesh, opt_specs)
        tok_spec = _dp_batch_spec(axes, mesh, b, None)
        tok_sh = NamedSharding(mesh, tok_spec)

        def train_step(params, opt, tokens, labels):
            loss, grads = TF.grads_fn(params, cfg, axes, tokens, labels)
            params, opt, metrics = adamw_update(opt_cfg, params, grads, opt)
            return params, opt, {"loss": loss, **metrics}

        return StepBundle(
            fn=train_step,
            abstract_args=(
                params_abs,
                opt_abs,
                jax.ShapeDtypeStruct((b, s), jnp.int32),
                jax.ShapeDtypeStruct((b, s), jnp.int32),
            ),
            in_shardings=(params_sh, opt_sh, tok_sh, tok_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
            description=f"train_step {cfg.name} B={b} S={s}",
        )

    if sh["step"] == "prefill":
        tok_sh = NamedSharding(mesh, _dp_batch_spec(axes, mesh, b, None))

        def prefill_step(params, tokens):
            return TF.prefill(params, cfg, axes, tokens)

        cache_sh = _named(mesh, TF.cache_specs(axes))
        return StepBundle(
            fn=prefill_step,
            abstract_args=(params_abs, jax.ShapeDtypeStruct((b, s), jnp.int32)),
            in_shardings=(params_sh, tok_sh),
            out_shardings=(None, cache_sh),
            description=f"serve_prefill {cfg.name} B={b} S={s}",
        )

    # decode: one new token against a KV cache of seq_len (or the window)
    cache_len = min(s, sh.get("window") or s)
    cache_abs = TF.cache_shapes(cfg, b, cache_len)
    cache_specs = TF.cache_specs(axes)
    if b == 1:  # batch-1 long-context: no dp sharding of batch
        cache_specs = {
            "k": P(None, None, axes.mp, None, None),
            "v": P(None, None, axes.mp, None, None),
            "pos": P(None, None, axes.mp),
        }
    cache_sh = _named(mesh, cache_specs)
    tok_sh = NamedSharding(mesh, _dp_batch_spec(axes, mesh, b, None))

    def decode(params, cache, token, pos):
        return TF.decode_step(params, cfg, axes, cache, token, pos)

    return StepBundle(
        fn=decode,
        abstract_args=(
            params_abs,
            cache_abs,
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ),
        in_shardings=(params_sh, cache_sh, tok_sh, tok_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
        description=f"serve_decode {cfg.name} B={b} cache={cache_len}",
    )


# ---------------------------------------------------------------------------
# GNN steps
# ---------------------------------------------------------------------------


def _pad512(n: int) -> int:
    """Round node/edge counts up to a multiple of 512 so every sharded dim
    divides both production meshes (padding rows are -1 / masked)."""
    return int(-(-n // 512) * 512)


def _gnn_graph_shape(arch: ArchConfig, shape_name: str,
                     model_cfg) -> GNN.GraphShape:
    sh = arch.shapes[shape_name]
    trip = DIMENET_TRIPLET_CAP.get(shape_name, 0) if model_cfg.kind == "dimenet" else 0
    if sh["step"] == "gnn_minibatch":
        b, (f1, f2) = sh["batch_nodes"], sh["fanouts"]
        n_nodes = b + b * f1 + b * f1 * f2
        n_edges = b * f1 + b * f1 * f2
        return GNN.GraphShape(_pad512(n_nodes), _pad512(n_edges), sh["d_feat"],
                              sh["n_classes"], trip)
    if sh["step"] == "gnn_molecule":
        nb = sh["batch"]
        return GNN.GraphShape(
            _pad512(sh["n_nodes"] * nb), _pad512(sh["n_edges"] * nb),
            sh["d_feat"], sh["n_classes"], trip, n_graphs=nb,
        )
    return GNN.GraphShape(_pad512(sh["n_nodes"]), _pad512(sh["n_edges"]),
                          sh["d_feat"], sh["n_classes"], trip)


def _gnn_bundle(arch: ArchConfig, shape_name: str, mesh: Mesh,
                opt_cfg: Optional[OptimizerConfig] = None,
                model_override=None) -> StepBundle:
    axes = MeshAxes.for_mesh(mesh)
    cfg: GNN.GNNConfig = model_override or arch.model
    gshape = _gnn_graph_shape(arch, shape_name, cfg)
    params_abs = jax.eval_shape(
        lambda k: GNN.init(k, cfg, gshape), jax.random.PRNGKey(0)
    )
    params_sh = _named(mesh, jax.tree.map(lambda x: P(*([None] * x.ndim)), params_abs))
    opt_cfg = opt_cfg or OptimizerConfig()
    opt_abs = jax.eval_shape(init_opt_state, params_abs)
    opt_sh = _named(
        mesh,
        {
            "mu": jax.tree.map(lambda x: P(*([None] * x.ndim)), params_abs),
            "nu": jax.tree.map(lambda x: P(*([None] * x.ndim)), params_abs),
            "step": P(),
        },
    )

    gspecs = GNN.graph_input_specs(gshape)
    all_axes = spec(axes, "dp+mp")  # node/edge dims over every mesh axis
    partitioned = (
        arch.shapes[shape_name].get("gnn_impl") == "partitioned"
        and cfg.kind == "dimenet"
    )
    edge_keys = ("edge_src", "edge_dst", "trip_kj", "trip_ji")

    def graph_spec(k, v):
        if partitioned and k not in edge_keys:
            return NamedSharding(mesh, P(*([None] * v.ndim)))  # replicated
        return NamedSharding(mesh, P(all_axes[0], *([None] * (v.ndim - 1))))

    graph_sh = {k: graph_spec(k, v) for k, v in gspecs.items()}

    if partitioned:
        axis_names = tuple(mesh.axis_names)

        def loss_fn(params, graph):
            return GNN.dimenet_loss_partitioned(params, cfg, graph, mesh, axis_names)
    else:
        def loss_fn(params, graph):
            return GNN.loss(params, cfg, graph)

    def train_step(params, opt, graph):
        loss, grads = jax.value_and_grad(loss_fn)(params, graph)
        params, opt, metrics = adamw_update(opt_cfg, params, grads, opt)
        return params, opt, {"loss": loss, **metrics}

    return StepBundle(
        fn=train_step,
        abstract_args=(params_abs, opt_abs, gspecs),
        in_shardings=(params_sh, opt_sh, graph_sh),
        out_shardings=(params_sh, opt_sh, None),
        donate_argnums=(0, 1),
        description=f"gnn train_step {cfg.name} N={gshape.n_nodes} E={gshape.n_edges}",
    )


# ---------------------------------------------------------------------------
# RecSys steps
# ---------------------------------------------------------------------------


def _recsys_bundle(arch: ArchConfig, shape_name: str, mesh: Mesh,
                   opt_cfg: Optional[OptimizerConfig] = None,
                   model_override=None) -> StepBundle:
    axes = MeshAxes.for_mesh(mesh)
    sh = arch.shapes[shape_name]
    cfg: DCN.DCNConfig = model_override or arch.model
    for knob in ("table_dtype", "qr_threshold"):
        if knob in sh:
            cfg = dataclasses.replace(cfg, **{knob: sh[knob]})
    b = sh["batch"]
    params_abs = jax.eval_shape(lambda k: DCN.init_params(cfg, k), jax.random.PRNGKey(0))
    pspecs = DCN.param_specs(cfg, axes)
    params_sh = _named(mesh, pspecs)
    dense_abs = jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32)
    sparse_abs = jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32)
    bspec = _dp_batch_spec(axes, mesh, b, None)
    dsh = NamedSharding(mesh, bspec)

    if sh["step"] == "recsys_train":
        opt_cfg = opt_cfg or OptimizerConfig()
        opt_abs = jax.eval_shape(init_opt_state, params_abs)
        opt_sh = _named(mesh, {"mu": pspecs, "nu": pspecs, "step": P()})
        lab_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
        lab_sh = NamedSharding(mesh, _dp_batch_spec(axes, mesh, b))

        def train_step(params, opt, dense, sparse, labels):
            loss, grads = jax.value_and_grad(DCN.loss_fn)(
                params, cfg, axes, dense, sparse, labels
            )
            params, opt, metrics = adamw_update(opt_cfg, params, grads, opt)
            return params, opt, {"loss": loss, **metrics}

        return StepBundle(
            fn=train_step,
            abstract_args=(params_abs, opt_abs, dense_abs, sparse_abs, lab_abs),
            in_shardings=(params_sh, opt_sh, dsh, dsh, lab_sh),
            out_shardings=(params_sh, opt_sh, None),
            donate_argnums=(0, 1),
            description=f"dcn train_step B={b}",
        )

    if sh["step"] == "recsys_serve":
        def serve(params, dense, sparse):
            return jax.nn.sigmoid(DCN.logits(params, cfg, axes, dense, sparse))

        return StepBundle(
            fn=serve,
            abstract_args=(params_abs, dense_abs, sparse_abs),
            in_shardings=(params_sh, dsh, dsh),
            out_shardings=None,
            description=f"dcn serve B={b}",
        )

    # retrieval: 1 query vs n_candidates
    nc = _pad512(sh["n_candidates"])
    d_q = cfg.mlp_dims[-1]
    cand_abs = jax.ShapeDtypeStruct((nc, d_q), jnp.float32)
    cand_sh = NamedSharding(mesh, P(spec(axes, "dp+mp")[0], None))

    def retrieve(params, dense, sparse, candidates):
        return DCN.retrieval_scores(params, cfg, axes, dense, sparse, candidates)

    return StepBundle(
        fn=retrieve,
        abstract_args=(params_abs, dense_abs, sparse_abs, cand_abs),
        in_shardings=(params_sh, NamedSharding(mesh, P(None, None)),
                      NamedSharding(mesh, P(None, None)), cand_sh),
        out_shardings=None,
        description=f"dcn retrieval 1x{nc}",
    )


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def build_step(arch: ArchConfig, shape_name: str, mesh: Mesh,
               opt_cfg: Optional[OptimizerConfig] = None,
               use_reduced: bool = False) -> StepBundle:
    override = arch.reduced_model if use_reduced else None
    if arch.kind == "lm":
        return _lm_bundle(arch, shape_name, mesh, opt_cfg, override)
    if arch.kind == "gnn":
        return _gnn_bundle(arch, shape_name, mesh, opt_cfg, override)
    if arch.kind == "recsys":
        return _recsys_bundle(arch, shape_name, mesh, opt_cfg, override)
    raise ValueError(arch.kind)
