"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
axis extends data parallelism across the inter-pod (DCN/ICI) boundary.

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with production axis names (CPU smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
