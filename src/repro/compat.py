"""jax version compatibility shims.

The training/distributed code targets the current jax API (`jax.shard_map`,
`jax.set_mesh`); older releases (<= 0.4.x, as in the pinned verification
container) spell these `jax.experimental.shard_map.shard_map` and use
`Mesh` itself as the ambient-mesh context manager. Route through here so
both work.
"""

from __future__ import annotations

import contextlib

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def set_mesh(mesh):
    """Ambient-mesh context manager across jax versions."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh  # 0.4.x: Mesh is a context manager
    return contextlib.nullcontext(mesh)
